"""Serving benchmark: the continuous-batching engine under Poisson arrivals.

Sweeps (max_batch, page_size) points on a tiny dense model, replaying the same
seeded request trace (prompt lengths from fixed buckets so prefill compiles a
bounded set of shapes; exponential inter-arrival gaps) and reports engine
throughput (tokens/sec) and request latency (p50/p99 end-to-end, p50/p99
time-to-first-token). Each point warms the jit cache with a short rehearsal run
so the measured pass times compiled code, then writes every point to
``BENCH_serving.json`` so the perf trajectory accumulates across PRs.

A second section replays a shared-prefix trace (every prompt opens with the
same system-prompt-style block) twice — prefix sharing on vs. off — and records
the peak pages-in-use of each plus the token-exactness of the shared run: the
copy-on-write paged cache should serve the burst from far fewer physical pages
(capacity O(unique tokens), not O(total tokens)).

A third section replays the same shared-prefix burst once per KV page
representation (f32 / int8 / int4 — EngineConfig.kv_dtype, the QuantizedAccessor
axis composed with LayoutPaged) and records peak pages, decode throughput, pool
bytes (the capacity_x_vs_f32 ratio is the pages-per-byte gain), greedy token
agreement, and the max |logit - logit_f32| over aligned steps — the
accuracy/capacity trade the CI smoke job gates on.

A fourth section is the LONG-PROMPT BURST: long prompts and short requests
arrive together, replayed through a monolithic-prefill engine and a
chunked-prefill (mixed-step) engine. Monolithic stalls every short request
behind whole-prompt prefills; chunked interleaves page-sized chunks with
decode, so the section records time-to-first-token p50/p95 and decode
throughput for both (the CI gate requires chunked TTFT p50 strictly better)
plus token-exactness between the two engines. A sub-section replays a
shared-prefix follower trace with prefill COMPUTE skip (the chunk cursor
starts past the adopted pages) and records prefill_tokens_skipped — the
prefill-FLOPs saved by prefix sharing, beyond the storage dedupe of PR 2.

A fifth section is the DECODE HOT PATH: a steady-state, batch-full decode
sweep (every slot decoding a long tail, no arrivals in flight) through one
engine per fused-decode horizon K (EngineConfig.multi_step). K=1 times the
device-resident single step — on-device sampling, persistent table/len
mirrors, (B,) ids as the only per-token D2H; K>1 amortizes dispatch over
K-step on-device loops. Records step_ms_p50/p95, the host-vs-device
breakdown (host_overhead_ms_p50), fused-step counts, and token-exactness
across every K; a sampled sub-section replays the trace with
temperature/top-k/top-p twice and asserts seeded reproducibility. Every
point's step timing now also carries step_ms_p95 + host_overhead_ms_p50 —
the breakdown the CI perf-ratchet uploads.

A sixth section is TELEMETRY: the steady-decode trace replayed through a
trace=off and a trace=on engine (same config otherwise). Records
step_ms_p50 for both and the overhead percentage — the "zero-overhead when
off, near-zero when on" claim the CI gate pins (trace=on p50 within 5% of
trace=off) — plus token-exactness between the two. The trace=on engine's
lifecycle trace is exported to ``artifacts/serving_trace.json`` as Chrome
trace-event JSON (open in Perfetto / chrome://tracing), schema-validated
in-process, and the per-event-name counts are reported so the trace can be
cross-checked against the engine's own metrics counters.

An eighth section is SPECULATIVE DECODING: the steady batch-full decode
trace replayed through a plain engine and a speculative one
(EngineConfig.spec_tokens=K — n-gram drafts verified in one chunk-kernel
call per window, lens-rollback accept) on two workloads. The REPETITIVE
workload zeroes every parameter except the embedding, pinning the greedy
stream to a constant — the deterministic best case for prompt-lookup
drafts (stand-in for the n-gram-heavy code/JSON/transcript streams the
technique targets); the CI gate requires accepted_tokens_per_step >= 1.2
and decode throughput STRICTLY above the plain baseline there. The
INCOMPRESSIBLE workload is the random-init bench model, whose greedy
stream has no n-gram structure — drafts always miss, every window commits
exactly one token, and the recorded regression vs baseline (gated <= 15%)
is the price of verification when speculation never pays. Both workloads
gate greedy token-exactness: speculative greedy output must equal the
plain engine's bit-for-bit.

A ninth section is HIERARCHICAL KV: the host-memory page tier behind the
accessor axis (EngineConfig.host_pool_pages). Session resume replays
finished sessions' follow-up turns through a retaining tiered engine
(prefetch-on-admission) and a tier-less one (full prefill recompute) and
records the TTFT pair — the CI gate requires resume strictly below
recompute. Oversubscription pushes ~10x more resumable work than the
device pool holds through a tiered engine and records sustained tokens/s
plus token-exactness against an unconstrained pool; tier-idle replays the
steady-decode trace with the tier enabled but untouched and records the
step-time overhead (the ≤5% zero-overhead discipline).

A seventh section is PARALLEL GENERATION: branch groups as layout forks.
Best-of-n (n=8) replays one group against n serial engines and records the
group's peak pages against the one-prompt-plus-n-tails page model (the CI
gate bounds the prompt-KV ratio at 1.25x) plus per-branch token exactness
against serial same-seed runs. Beam search (width 4) records survivor
reorders, CoW copies, and the compile-cache delta of the measured run — the
reorder is a device-mirror row permutation, so the gate requires reorders > 0
with ZERO new compiles. Constrained decoding runs a JSON-array token DFA and
gates on 100% of outputs parsing.

  PYTHONPATH=src python -m benchmarks.run --only serving
  PYTHONPATH=src python -m benchmarks.run --only serving --smoke   # CI-sized
  PYTHONPATH=src python -m benchmarks.run --only serving --smoke --kv-dtype int8
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import ModelConfig, Model
from repro.serving import (
    JSON_ARRAY_CHARS, GenerationParams, fixed_json_array_dfa,
)
from repro.serving.engine import (
    EngineConfig, Request, SamplingParams, ServeEngine, aligned_max_logit_err,
    validate_chrome_trace,
)

# bumped whenever a report key is added/renamed/retyped; CI validates it and
# the smoke/full reports carry the IDENTICAL schema (same keys, same shapes —
# smoke only shrinks sizes), so any consumer can read either file
SCHEMA_VERSION = 4

OUT_PATH = Path("BENCH_serving.json")
TRACE_PATH = Path("artifacts/serving_trace.json")  # gitignored; CI uploads it
SMOKE_OUT_PATH = Path("BENCH_serving_smoke.json")  # COMMITTED: the CI
# perf-ratchet baseline (bench-smoke fails on step_ms_p50 +20% / tokens_per_s
# -10% vs this file). Smoke runs still never clobber the full-size cross-PR
# trajectory in BENCH_serving.json; regenerate + commit the smoke file when a
# PR intentionally moves decode perf

POINTS = [  # (max_batch, page_size)
    (2, 8),
    (4, 8),
    (4, 16),
]

PROMPT_BUCKETS = (8, 16, 24)
N_REQUESTS = 10
MAX_NEW_TOKENS = 8
MEAN_ARRIVAL_GAP_S = 0.02

# shared-prefix section: a common block + short unique tails, arriving in a
# burst. The prefix is NOT page-aligned and the 0 tail bucket repeats it
# verbatim, so some requests share even the partial last page and the first
# decode append exercises copy-on-write.
SHARED_PREFIX_LEN = 34
SHARED_TAIL_BUCKETS = (0, 4, 8)
SHARED_N_REQUESTS = 8
SHARED_MAX_BATCH = 4
SHARED_PAGE_SIZE = 8

# long-prompt burst: a few long prompts and many short requests arrive at once.
# Monolithic prefill serializes the long prompts in front of everything; the
# chunked engine advances them CHUNK_TOKENS per mixed step while the shorts
# prefill and decode in between — the TTFT distribution is the point. This
# section uses its own, slightly larger model: chunking pays one dispatch per
# chunk, so its win only shows where prefill COMPUTE dominates dispatch
# overhead (d_model 128, 896-token prompts: a monolithic prefill costs well
# over an order of magnitude more than a chunk step on CPU) — on the
# dispatch-bound smoke model every schedule ties.
LONG_PROMPT_LEN = 896
LONG_N = 2
SHORT_PROMPT_LEN = 8
SHORT_N = 6
BURST_PAGE_SIZE = 8
# every burst request gets a slot at t=0: the section isolates prefill
# head-of-line blocking (what chunking fixes) from slot-turnover contention
# (which a per-step chunk budget inherently slows — tokens_per_s records
# that trade)
BURST_MAX_BATCH = 8
CHUNK_TOKENS = 128

# decode hot path: steady-state, batch-full decode — every slot holds a short
# prompt and decodes a long tail with no arrivals, admissions or page events
# in flight beyond routine page appends. This isolates the per-token decode
# cost the device-resident refactor targets: host argmax + full-logits D2H +
# per-step table uploads before, (B,) sampled ids after. Ks sweep the fused
# horizon (multi_step); K=1 is the single-dispatch engine.
STEADY_PROMPT_LEN = 8
STEADY_NEW_TOKENS = 48
STEADY_MAX_BATCH = 4
STEADY_PAGE_SIZE = 16
MULTI_STEP_KS = (1, 2, 4, 8)

# speculative decoding: the steady-decode trace through a plain and a
# spec_tokens=K engine. K=3 drafts + the current token make a 4-wide verify
# window; multi_step=2 fuses two windows per dispatch (the spec engine's
# steady state is zero-D2H across both). The decode tail is LONGER than the
# steady section's: the throughput gate compares end-to-end tokens/s, so the
# decode phase (where speculation wins) must dominate the shared prefill cost,
# and each engine's measured trace repeats SPEC_PASSES times keeping the best
# (host interference only ever subtracts throughput — the max recovers each
# engine's capability, same estimator as the perf matrix's min-of-5).
SPEC_TOKENS = 3
SPEC_MULTI_STEP = 2
SPEC_NEW_TOKENS = 96
SPEC_PASSES = 3

# hierarchical KV: the host-memory page tier (EngineConfig.host_pool_pages).
# Session RESUME replays finished sessions' follow-ups through a retaining
# tiered engine (prefetch-on-admission promotes the retained pages) and a
# tier-less engine (full prefill recompute) — the TTFT pair is the headline
# number and runs on the burst model, where prefill compute dominates
# dispatch. OVERSUBSCRIPTION admits ~10x more resumable work than the device
# pool holds (the tiered pool is a ~10th of what the trace needs; the
# unconstrained reference holds everything) and records sustained tokens/s +
# token-exactness under constant preempt-demote/promote churn. IDLE replays
# the steady-decode trace with the tier configured but untouched — the
# zero-overhead-when-idle discipline (step_ms_p50 within 5% of tier-off).
HK_PAGE_SIZE = 8
HK_CHUNK_TOKENS = 32
HK_SESSION_LEN = 192
HK_N_SESSIONS = 3
HK_TAIL = 8
HK_MAX_NEW = 6
HK_OS_PROMPT_LEN = 16  # small prompt + long decode tail: admission is cheap
HK_OS_N_REQUESTS = 16  # but growth collides mid-flight, forcing the
HK_OS_MAX_NEW = 24     # preempt-demote / readmit-promote churn the section is
HK_OS_MAX_BATCH = 4    # about (a big prompt would just serialize admissions)
HK_IDLE_NEW_TOKENS = 32

# parallel generation: branch groups as layout forks. Best-of-n forks the
# prompt's block-table rows so all n branches alias one prompt's pages (the
# page gate below: group peak ≈ one prompt + n decode tails, NOT n prompts);
# beam search reorders block-table rows between steps (a device-mirror
# permutation — no page copies, no recompiles); constrained decoding masks
# logits on device through a host-compiled token DFA.
BRANCH_N = 8
BRANCH_PROMPT_LEN = 24
BRANCH_NEW_TOKENS = 6
BRANCH_PAGE_SIZE = 4
BEAM_WIDTH = 4
BEAM_PROMPT_LEN = 8
BEAM_NEW_TOKENS = 8
GRAMMAR_N_REQUESTS = 4
GRAMMAR_NEW_TOKENS = 12


def burst_config() -> ModelConfig:
    return ModelConfig(
        name="bench-burst-dense", family="dense", n_layers=2, d_model=128,
        vocab=512, n_heads=4, n_kv_heads=2, d_ff=256, dtype="float32",
    )


def bench_config(smoke: bool = False) -> ModelConfig:
    if smoke:
        return ModelConfig(
            name="bench-tiny-dense-smoke", family="dense", n_layers=1, d_model=32,
            vocab=256, n_heads=2, n_kv_heads=2, d_ff=64, dtype="float32",
        )
    return ModelConfig(
        name="bench-tiny-dense", family="dense", n_layers=2, d_model=64,
        vocab=512, n_heads=4, n_kv_heads=2, d_ff=128, dtype="float32",
    )


def make_requests(rng: np.random.Generator, vocab: int, n: int,
                  max_new: int = MAX_NEW_TOKENS) -> list:
    gaps = rng.exponential(scale=MEAN_ARRIVAL_GAP_S, size=n)
    arrivals = np.cumsum(gaps)
    reqs = []
    for i in range(n):
        length = int(rng.choice(PROMPT_BUCKETS))
        prompt = rng.integers(0, vocab, size=length).tolist()
        reqs.append(
            Request(
                    rid=i,
                    prompt=prompt,
                    params=GenerationParams(max_new_tokens=max_new),
                    arrival_time=float(arrivals[i]),
                )
        )
    return reqs


def make_shared_prefix_requests(rng: np.random.Generator, vocab: int, n: int,
                                max_new: int) -> list:
    prefix = rng.integers(0, vocab, size=SHARED_PREFIX_LEN).tolist()
    # round-robin tail lengths so every bucket appears: the 0-tail requests are
    # verbatim prompt repeats (maximal sharing + forced CoW), the rest diverge
    tails = [SHARED_TAIL_BUCKETS[i % len(SHARED_TAIL_BUCKETS)] for i in range(n)]
    return [
        Request(
                rid=i,
                prompt=prefix + rng.integers(0, vocab, size=tails[i]).tolist(),
                params=GenerationParams(max_new_tokens=max_new),
                arrival_time=0.0,
            )
        for i in range(n)
    ]


def engine_for(model, params, max_batch: int, page_size: int,
               max_new: int, **kw) -> ServeEngine:
    max_len = max(PROMPT_BUCKETS) + max_new + 1
    return ServeEngine(
        model, params,
        EngineConfig.sized_for(max_len, page_size=page_size, max_batch=max_batch, **kw),
    )


def run_shared_prefix(model, params, vocab: int, n_requests: int,
                      max_new: int) -> dict:
    """The same burst through a sharing and a non-sharing engine; returns peak
    pages-in-use for both, the savings, and whether outputs were token-exact."""
    max_len = SHARED_PREFIX_LEN + max(SHARED_TAIL_BUCKETS) + max_new + 1
    conf = EngineConfig.sized_for(
        max_len, page_size=SHARED_PAGE_SIZE, max_batch=SHARED_MAX_BATCH,
    )
    outputs = {}
    stats = {}
    for mode, sharing in (("sharing_on", True), ("sharing_off", False)):
        eng = ServeEngine(
            model, params, dataclasses.replace(conf, prefix_sharing=sharing)
        )
        # rehearsal (same trace) compiles every prefill bucket + the decode
        # step, then reset: measured throughput times compiled code, and the
        # rehearsal's pages all freed so the index/peak start clean
        eng.run(make_shared_prefix_requests(np.random.default_rng(7), vocab,
                                            n_requests, max_new))
        eng.reset_metrics()
        rng = np.random.default_rng(7)
        results = eng.run(make_shared_prefix_requests(rng, vocab, n_requests, max_new))
        outputs[mode] = {rid: s.generated for rid, s in results.items()}
        m = eng.metrics()
        stats[mode] = m
    on, off = stats["sharing_on"], stats["sharing_off"]
    savings = 1.0 - on["peak_pages_in_use"] / max(off["peak_pages_in_use"], 1)
    return {
        "n_requests": n_requests,
        "prefix_len": SHARED_PREFIX_LEN,
        "page_size": SHARED_PAGE_SIZE,
        "max_batch": SHARED_MAX_BATCH,
        "peak_pages_sharing_on": on["peak_pages_in_use"],
        "peak_pages_sharing_off": off["peak_pages_in_use"],
        "peak_pages_saved_pct": round(100.0 * savings, 1),
        "pages_shared": on["pages_shared"],
        "cow_copies": on["cow_copies"],
        "tokens_per_s_sharing_on": on["tokens_per_s"],
        "tokens_per_s_sharing_off": off["tokens_per_s"],
        "tokens_exact": outputs["sharing_on"] == outputs["sharing_off"],
    }


def run_quantized(model, params, vocab: int, n_requests: int, max_new: int,
                  kv_dtypes) -> dict:
    """The same shared-prefix burst through one engine per KV representation;
    f32 is the accuracy/capacity baseline the others are scored against."""
    max_len = SHARED_PREFIX_LEN + max(SHARED_TAIL_BUCKETS) + max_new + 1
    conf = EngineConfig.sized_for(
        max_len, page_size=SHARED_PAGE_SIZE, max_batch=SHARED_MAX_BATCH,
        record_logits=True,
    )
    engines, results, metrics = {}, {}, {}
    for kv in kv_dtypes:
        eng = ServeEngine(model, params, dataclasses.replace(conf, kv_dtype=kv))
        # rehearsal compiles prefill buckets + this dtype's decode step, then
        # reset so the measured pass times compiled code on a clean pool
        eng.run(make_shared_prefix_requests(np.random.default_rng(7), vocab,
                                            n_requests, max_new))
        eng.reset_metrics()
        results[kv] = eng.run(
            make_shared_prefix_requests(np.random.default_rng(7), vocab,
                                        n_requests, max_new)
        )
        engines[kv], metrics[kv] = eng, eng.metrics()
    f32 = metrics["f32"]
    section = {
        "n_requests": n_requests,
        "prefix_len": SHARED_PREFIX_LEN,
        "page_size": SHARED_PAGE_SIZE,
        "max_new_tokens": max_new,
        "dtypes": {},
    }
    for kv in kv_dtypes:
        m = metrics[kv]
        entry = {
            "peak_pages_in_use": m["peak_pages_in_use"],
            "pages_shared": m["pages_shared"],
            "tokens_per_s": m["tokens_per_s"],
            "step_ms_p50": m["step_ms_p50"],
            "kv_pool_bytes": m["kv_pool_bytes"],
        }
        if kv != "f32":
            entry["capacity_x_vs_f32"] = round(
                f32["kv_pool_bytes"] / m["kv_pool_bytes"], 2
            )
            entry["max_logit_err_vs_f32"] = aligned_max_logit_err(
                engines["f32"], engines[kv], results["f32"], results[kv]
            )
            entry["tokens_exact_vs_f32"] = all(
                results[kv][r].generated == results["f32"][r].generated
                for r in results["f32"]
            )
        section["dtypes"][kv] = entry
    return section


def make_long_burst_requests(rng: np.random.Generator, vocab: int, n_long: int,
                             n_short: int, max_new: int) -> list:
    """Long prompts first in FIFO order, shorts right behind — all at t=0, the
    worst case for monolithic prefill (every short stalls behind whole-prompt
    prefills)."""
    reqs = []
    for i in range(n_long):
        reqs.append(Request(
                rid=i,
                prompt=rng.integers(0, vocab, size=LONG_PROMPT_LEN).tolist(),
                params=GenerationParams(max_new_tokens=max_new),
                arrival_time=0.0,
            ))
    for i in range(n_short):
        reqs.append(Request(
                rid=n_long + i,
                prompt=rng.integers(0, vocab, size=SHORT_PROMPT_LEN).tolist(),
                params=GenerationParams(max_new_tokens=max_new),
                arrival_time=0.0,
            ))
    return reqs


def make_skip_requests(rng: np.random.Generator, vocab: int, max_new: int) -> list:
    """Donor / filler / followers: the donor's long shared prefix is resident
    (and published chunk-by-chunk) while it decodes; the filler frees its slot
    so the followers admit MID-donor and adopt — the deterministic pattern that
    exercises prefill compute skip without wall-clock staging."""
    prefix = rng.integers(0, vocab, size=32).tolist()
    return [
        Request(
                rid=0,
                prompt=prefix + rng.integers(0, vocab, size=4).tolist(),
                params=GenerationParams(max_new_tokens=3 * max_new),
                arrival_time=0.0,
            ),
        Request(
                rid=1,
                prompt=rng.integers(0, vocab, size=5).tolist(),
                params=GenerationParams(max_new_tokens=2),
                arrival_time=0.0,
            ),
        Request(
                rid=2,
                prompt=prefix + rng.integers(0, vocab, size=3).tolist(),
                params=GenerationParams(max_new_tokens=max_new),
                arrival_time=0.0,
            ),
        Request(
                rid=3,
                prompt=list(prefix),
                params=GenerationParams(max_new_tokens=max_new),
                arrival_time=0.0,
            ),
    ]


def run_long_prompt_burst(max_new: int, n_long: int, n_short: int) -> dict:
    """The same burst through a monolithic and a chunked (mixed-step) engine;
    records TTFT p50/p95 and decode throughput for both, token-exactness, and
    a compute-skip sub-section (prefill FLOPs saved under shared prefixes).
    Runs on its own burst_config() model (see the constant block above)."""
    cfg = burst_config()
    model = Model(cfg)
    params = model.init_params(jax.random.key(1))
    vocab = cfg.vocab
    max_len = LONG_PROMPT_LEN + 3 * max_new + 1
    conf = EngineConfig.sized_for(
        max_len, page_size=BURST_PAGE_SIZE, max_batch=BURST_MAX_BATCH,
    )
    confs = {
        "monolithic": conf,
        "chunked": dataclasses.replace(
            conf, chunked_prefill=True, chunk_tokens=CHUNK_TOKENS
        ),
    }
    outputs, stats = {}, {}
    for mode, c in confs.items():
        eng = ServeEngine(model, params, c)
        # rehearsal compiles this mode's prefill shapes (monolithic: one per
        # page bucket; chunked: the single chunk step) + decode, then reset
        eng.run(make_long_burst_requests(np.random.default_rng(11), vocab,
                                         n_long, n_short, max_new))
        eng.reset_metrics()
        results = eng.run(
            make_long_burst_requests(np.random.default_rng(11), vocab,
                                     n_long, n_short, max_new)
        )
        outputs[mode] = {rid: s.generated for rid, s in results.items()}
        stats[mode] = eng.metrics()
    mono, chk = stats["monolithic"], stats["chunked"]
    # compute-skip sub-section: chunked engine, shared-prefix followers
    skip_conf = dataclasses.replace(confs["chunked"], max_batch=2)
    eng = ServeEngine(model, params, skip_conf)
    eng.run(make_skip_requests(np.random.default_rng(13), vocab, max_new))
    eng.reset_metrics()
    skip_results = eng.run(make_skip_requests(np.random.default_rng(13), vocab, max_new))
    m_skip = eng.metrics()
    eng_cold = ServeEngine(
        model, params, dataclasses.replace(skip_conf, prefix_sharing=False)
    )
    cold_results = eng_cold.run(make_skip_requests(np.random.default_rng(13), vocab, max_new))
    skip_total = m_skip["prefill_tokens_skipped"] + m_skip["prefill_tokens_computed"]
    return {
        "n_long": n_long,
        "n_short": n_short,
        "long_prompt_len": LONG_PROMPT_LEN,
        "short_prompt_len": SHORT_PROMPT_LEN,
        "chunk_tokens": CHUNK_TOKENS,
        "page_size": BURST_PAGE_SIZE,
        "max_batch": BURST_MAX_BATCH,
        "ttft_s_p50_monolithic": mono["ttft_s_p50"],
        "ttft_s_p50_chunked": chk["ttft_s_p50"],
        "ttft_s_p95_monolithic": mono["ttft_s_p95"],
        "ttft_s_p95_chunked": chk["ttft_s_p95"],
        "ttft_p50_speedup_x": round(
            mono["ttft_s_p50"] / max(chk["ttft_s_p50"], 1e-9), 2
        ),
        "tokens_per_s_monolithic": mono["tokens_per_s"],
        "tokens_per_s_chunked": chk["tokens_per_s"],
        "decode_steps_chunked": chk["decode_steps"],
        "tokens_exact": outputs["monolithic"] == outputs["chunked"],
        "prefix_compute_skip": {
            "prefill_tokens_skipped": m_skip["prefill_tokens_skipped"],
            "prefill_tokens_computed": m_skip["prefill_tokens_computed"],
            "prefill_flops_saved_pct": round(
                100.0 * m_skip["prefill_tokens_skipped"] / max(skip_total, 1), 1
            ),
            "pages_shared": m_skip["pages_shared"],
            "tokens_exact_vs_cold": {
                r: skip_results[r].generated for r in skip_results
            } == {r: cold_results[r].generated for r in cold_results},
        },
    }


def run_hierarchical_kv(smoke: bool) -> dict:
    """The host page tier measured three ways (see the constant block above):
    session-resume TTFT vs recompute, sustained decode under ~10x pool
    oversubscription vs an unconstrained pool, and the enabled-but-idle
    step-time overhead. Runs on its own burst_config() model so prefill
    compute — what resume-prefetch avoids — dominates dispatch overhead."""
    cfg = burst_config()
    model = Model(cfg)
    params = model.init_params(jax.random.key(2))
    vocab = cfg.vocab
    session_len = HK_SESSION_LEN // 2 if smoke else HK_SESSION_LEN
    n_sessions = 2 if smoke else HK_N_SESSIONS
    # --- session resume: prefetch vs recompute -------------------------------
    rng = np.random.default_rng(31)
    sessions = [rng.integers(0, vocab, size=session_len).tolist()
                for _ in range(n_sessions)]
    max_len = session_len + HK_TAIL + 2 * HK_MAX_NEW + 2
    conf = EngineConfig.sized_for(
        max_len, page_size=HK_PAGE_SIZE, max_batch=n_sessions,
        chunked_prefill=True, chunk_tokens=HK_CHUNK_TOKENS,
    )
    tiered_conf = dataclasses.replace(
        conf,
        host_pool_pages=4 * n_sessions * (max_len // HK_PAGE_SIZE),
        retain_finished_s=600.0,
    )
    first = lambda: [
        Request(rid=i, prompt=list(p),
                params=GenerationParams(max_new_tokens=HK_MAX_NEW))
        for i, p in enumerate(sessions)
    ]
    outputs, stats = {}, {}
    resume_prompts = None
    for mode, c in (("resume_prefetch", tiered_conf), ("recompute", conf)):
        eng = ServeEngine(model, params, c)
        res1 = eng.run(first())
        if resume_prompts is None:
            # the follow-up turn: old context + the reply + a fresh user tail
            resume_prompts = [
                sessions[i] + res1[i].generated
                + rng.integers(0, vocab, size=HK_TAIL).tolist()
                for i in range(n_sessions)
            ]
        resume = lambda: [
            Request(rid=100 + i, prompt=list(p),
                    params=GenerationParams(max_new_tokens=HK_MAX_NEW))
            for i, p in enumerate(resume_prompts)
        ]
        # Two rehearsal resumes, because the tiered engine's tier state moves
        # once: after the first, retention has demoted the resume context
        # itself, so the second resume promotes the full prompt run and
        # computes only the final partial chunk — the same shapes (and hence
        # the same compiled code) the measured pass uses. A single rehearsal
        # would leave a fresh chunk-bucket compile inside the timed region.
        eng.run(resume())
        eng.run(resume())
        eng.reset_metrics()
        results = eng.run(resume())
        outputs[mode] = {rid: s.generated for rid, s in results.items()}
        stats[mode] = eng.metrics()
    warm, cold = stats["resume_prefetch"], stats["recompute"]
    resume_sec = {
        "session_len": session_len,
        "n_sessions": n_sessions,
        "page_size": HK_PAGE_SIZE,
        "chunk_tokens": HK_CHUNK_TOKENS,
        "host_pool_pages": tiered_conf.host_pool_pages,
        "retain_finished_s": tiered_conf.retain_finished_s,
        "ttft_s_p50_resume": warm["ttft_s_p50"],
        "ttft_s_p50_recompute": cold["ttft_s_p50"],
        "resume_ttft_speedup_x": round(
            cold["ttft_s_p50"] / max(warm["ttft_s_p50"], 1e-9), 2
        ),
        "prefetch_hits": warm["prefetch_hits"],
        "swap_in_pages": warm["swap_in_pages"],
        "prefill_tokens_computed_resume": warm["prefill_tokens_computed"],
        "prefill_tokens_computed_recompute": cold["prefill_tokens_computed"],
        "tokens_exact": outputs["resume_prefetch"] == outputs["recompute"],
    }
    # --- ~10x oversubscription: sustained decode under swap churn ------------
    os_n = HK_OS_N_REQUESTS // 2 if smoke else HK_OS_N_REQUESTS
    # steady-state footprint per sequence vs. the static per-seq cap submit()
    # checks (prompt + max_new + 1 lookahead token)
    need_pages = -(-(HK_OS_PROMPT_LEN + HK_OS_MAX_NEW) // HK_PAGE_SIZE)
    seq_cap_pages = -(-(HK_OS_PROMPT_LEN + HK_OS_MAX_NEW + 1) // HK_PAGE_SIZE)
    os_rng = np.random.default_rng(33)
    os_prompts = [os_rng.integers(0, vocab, size=HK_OS_PROMPT_LEN).tolist()
                  for _ in range(os_n)]
    os_reqs = lambda: [
        Request(rid=i, prompt=list(p),
                params=GenerationParams(max_new_tokens=HK_OS_MAX_NEW))
        for i, p in enumerate(os_prompts)
    ]
    demand_pages = os_n * need_pages
    # tight pool: ~demand/10, but always roomy enough to ADMIT two requests
    # concurrently (admission allocates pages_for(prompt+1), plus the
    # scheduler's one-page watermark) — their decode growth then collides,
    # which is what forces the preempt-demote / readmit-promote churn
    admit_pages = -(-(HK_OS_PROMPT_LEN + 1) // HK_PAGE_SIZE)
    tight_usable = max(demand_pages // 10, 2 * admit_pages + 1)
    mk_conf = lambda usable, host: EngineConfig(
        num_pages=usable + 1, page_size=HK_PAGE_SIZE,
        max_batch=HK_OS_MAX_BATCH, max_pages_per_seq=seq_cap_pages,
        host_pool_pages=host,
    )
    os_outputs, os_stats = {}, {}
    for mode, c in (
        ("oversubscribed", mk_conf(tight_usable, demand_pages)),
        ("unconstrained", mk_conf(demand_pages, 0)),
    ):
        eng = ServeEngine(model, params, c)
        eng.run(os_reqs())  # rehearsal: compile + (tiered) warm the host tier
        eng.reset_metrics()
        results = eng.run(os_reqs())
        os_outputs[mode] = {rid: s.generated for rid, s in results.items()}
        os_stats[mode] = eng.metrics()
    over, free_pool = os_stats["oversubscribed"], os_stats["unconstrained"]
    os_sec = {
        "n_requests": os_n,
        "prompt_len": HK_OS_PROMPT_LEN,
        "max_new_tokens": HK_OS_MAX_NEW,
        "max_batch": HK_OS_MAX_BATCH,
        "pool_pages_oversubscribed": tight_usable,
        "pool_pages_unconstrained": demand_pages,
        "oversubscription_x": round(demand_pages / tight_usable, 1),
        "tokens_per_s_oversubscribed": over["tokens_per_s"],
        "tokens_per_s_unconstrained": free_pool["tokens_per_s"],
        "throughput_retained_pct": round(
            100.0 * over["tokens_per_s"]
            / max(free_pool["tokens_per_s"], 1e-9), 1
        ),
        "preemptions": over["preemptions"],
        "swap_out_pages": over["swap_out_pages"],
        # demotions the content index made write-back-free: the preempted
        # pages' keys were already host-resident, so nothing was copied
        "swap_out_elided": over["swap_out_elided"],
        "swap_in_pages": over["swap_in_pages"],
        "prefetch_hits": over["prefetch_hits"],
        "evictions": over["evictions"],
        "tokens_exact": os_outputs["oversubscribed"]
        == os_outputs["unconstrained"],
    }
    # --- enabled-but-idle overhead: the zero-overhead discipline -------------
    idle_new = HK_IDLE_NEW_TOKENS // 2 if smoke else HK_IDLE_NEW_TOKENS
    idle_make = lambda: [
        Request(
            rid=i,
            prompt=np.random.default_rng(170 + i).integers(
                0, vocab, size=STEADY_PROMPT_LEN
            ).tolist(),
            params=GenerationParams(max_new_tokens=idle_new),
        )
        for i in range(STEADY_MAX_BATCH)
    ]
    idle_conf = EngineConfig.sized_for(
        STEADY_PROMPT_LEN + idle_new + 1, page_size=STEADY_PAGE_SIZE,
        max_batch=STEADY_MAX_BATCH, multi_step=4,
    )
    idle_engines = {
        mode: ServeEngine(
            model, params,
            dataclasses.replace(idle_conf, host_pool_pages=host),
        )
        for mode, host in (("tier_off", 0), ("tier_on_idle", 64))
    }
    for eng in idle_engines.values():  # compile both before any timing
        eng.run(idle_make())
    # The idle delta is tens of microseconds on sub-millisecond dispatches, so
    # a single pass is dominated by OS scheduling jitter: interleave several
    # passes and take each mode's best p50 (min over passes is the standard
    # microbenchmark de-noiser — jitter only ever adds time).
    idle_passes = 3 if smoke else 5
    p50s: dict = {mode: [] for mode in idle_engines}
    idle_stats = {}
    for _ in range(idle_passes):
        for mode, eng in idle_engines.items():
            eng.reset_metrics()
            eng.run(idle_make())
            idle_stats[mode] = eng.metrics()
            p50s[mode].append(idle_stats[mode]["step_ms_p50"])
    off_p50 = min(p50s["tier_off"])
    on_p50 = min(p50s["tier_on_idle"])
    idle_sec = {
        "new_tokens": idle_new,
        "multi_step": 4,
        "measure_passes": idle_passes,
        "step_ms_p50_tier_off": off_p50,
        "step_ms_p50_tier_on_idle": on_p50,
        "idle_overhead_pct": round(
            100.0 * (on_p50 - off_p50) / max(off_p50, 1e-9), 2
        ),
        "tier_untouched": (
            idle_stats["tier_on_idle"]["swap_out_pages"] == 0
            and idle_stats["tier_on_idle"]["swap_in_pages"] == 0
        ),
    }
    return {
        "session_resume": resume_sec,
        "oversubscription": os_sec,
        "tier_idle": idle_sec,
    }


def run_steady_decode(model, params, vocab: int, n_new: int, ks) -> dict:
    """Steady-state batch-full decode through one engine per fused horizon K.
    K=1 is the single-dispatch device-resident step; larger K runs K-step
    on-device loops over scheduler-proven event-free horizons. Asserts token
    exactness across every K (greedy), then replays the trace SAMPLED
    (temperature/top-k/top-p) twice at the largest K to demonstrate the
    sampled-serving scenario and its seeded reproducibility."""
    make = lambda sampling=None: [
        Request(
            rid=i,
            prompt=np.random.default_rng(50 + i).integers(
                0, vocab, size=STEADY_PROMPT_LEN
            ).tolist(),
            params=GenerationParams.from_legacy(
                max_new_tokens=n_new, sampling=sampling
            ),
        )
        for i in range(STEADY_MAX_BATCH)
    ]
    conf = EngineConfig.sized_for(
        STEADY_PROMPT_LEN + n_new + 1, page_size=STEADY_PAGE_SIZE,
        max_batch=STEADY_MAX_BATCH,
    )
    section = {
        "prompt_len": STEADY_PROMPT_LEN,
        "new_tokens": n_new,
        "max_batch": STEADY_MAX_BATCH,
        "page_size": STEADY_PAGE_SIZE,
        "ks": {},
    }
    outputs = {}
    for k in ks:
        eng = ServeEngine(model, params, dataclasses.replace(conf, multi_step=k))
        eng.run(make())  # rehearsal: compile the step (and the K-loop), warm pools
        eng.reset_metrics()
        results = eng.run(make())
        outputs[k] = {rid: s.generated for rid, s in results.items()}
        m = eng.metrics()
        section["ks"][str(k)] = {
            "step_ms_p50": m["step_ms_p50"],
            "step_ms_p95": m["step_ms_p95"],
            "host_overhead_ms_p50": m["host_overhead_ms_p50"],
            "tokens_per_s": m["tokens_per_s"],
            "decode_steps": m["decode_steps"],
            "fused_steps": m["fused_steps"],
        }
    base = ks[0]
    section["tokens_exact_across_ks"] = all(outputs[k] == outputs[base] for k in ks)
    for k in ks[1:]:
        section["ks"][str(k)]["step_speedup_x_vs_k1"] = round(
            section["ks"][str(base)]["step_ms_p50"]
            / max(section["ks"][str(k)]["step_ms_p50"], 1e-9), 2
        )
    # sampled serving (the scenario on-device sampling opens): seeded
    # temperature/top-k/top-p through the fused engine, reproducible run-to-run
    sp = SamplingParams(temperature=0.8, top_k=40, top_p=0.95, seed=1234)
    k_top = ks[-1]
    eng = ServeEngine(model, params, dataclasses.replace(conf, multi_step=k_top))
    eng.run(make(sp))
    eng.reset_metrics()
    res_a = eng.run(make(sp))
    m_samp = eng.metrics()
    res_b = ServeEngine(
        model, params, dataclasses.replace(conf, multi_step=k_top)
    ).run(make(sp))
    section["sampled"] = {
        "temperature": sp.temperature,
        "top_k": sp.top_k,
        "top_p": sp.top_p,
        "multi_step": k_top,
        "step_ms_p50": m_samp["step_ms_p50"],
        "tokens_per_s": m_samp["tokens_per_s"],
        "reproducible": all(
            res_a[r].generated == res_b[r].generated for r in res_a
        ),
        "diverges_from_greedy": any(
            res_a[r].generated != outputs[base][r] for r in res_a
        ),
    }
    return section


def run_speculative(model, params, vocab: int, n_new: int) -> dict:
    """Steady batch-full decode through a plain and a speculative engine on a
    repetitive and an incompressible workload.

    Repetitive: every parameter zeroed except the embedding — the zeroed
    final-norm scale pins logits to 0 and greedy argmax to a constant token,
    so prompt-lookup drafts hit almost every window (the deterministic
    stand-in for n-gram-heavy real streams: code, JSON, chat transcripts).
    Incompressible: the random-init bench params, whose greedy stream has no
    n-gram repeats — drafts always miss and every window commits exactly one
    token, isolating the verify-kernel overhead speculation pays when it
    never wins. Both workloads assert greedy token-exactness between the two
    engines — the speculative correctness law CI pins."""
    make = lambda: [
        Request(
            rid=i,
            prompt=np.random.default_rng(130 + i).integers(
                0, vocab, size=STEADY_PROMPT_LEN
            ).tolist(),
            params=GenerationParams(max_new_tokens=n_new),
        )
        for i in range(STEADY_MAX_BATCH)
    ]
    conf = EngineConfig.sized_for(
        STEADY_PROMPT_LEN + n_new + 1, page_size=STEADY_PAGE_SIZE,
        max_batch=STEADY_MAX_BATCH, multi_step=SPEC_MULTI_STEP,
    )
    sconf = dataclasses.replace(conf, spec_tokens=SPEC_TOKENS)
    repetitive = dict(jax.tree.map(jnp.zeros_like, params))
    repetitive["embed"] = params["embed"]
    section = {
        "spec_tokens": SPEC_TOKENS,
        "multi_step": SPEC_MULTI_STEP,
        "prompt_len": STEADY_PROMPT_LEN,
        "new_tokens": n_new,
        "max_batch": STEADY_MAX_BATCH,
        "page_size": STEADY_PAGE_SIZE,
        "workloads": {},
    }
    for name, p in (("repetitive", repetitive), ("incompressible", params)):
        outs, stats = {}, {}
        engines = {
            "baseline": ServeEngine(model, p, conf),
            "speculative": ServeEngine(model, p, sconf),
        }
        for mode, eng in engines.items():
            # rehearsal compiles prefill buckets + the plain fused step + (spec
            # engine) the propose->verify->accept window, then reset: the
            # measured passes time compiled code on a clean pool
            eng.run(make())
        # interleaved best-of-N passes: the two engines alternate so a host
        # interference burst hits both equally, and the best pass per engine
        # recovers its capability (noise only ever subtracts throughput).
        # Greedy decode is deterministic, so every pass yields the same
        # tokens — exactness reads the last pass, counters read the last
        # pass too (per-pass reset keeps window/acceptance rates unskewed)
        # decode throughput = decode tokens (every generated token minus the
        # per-request prefill first-token) over the SUMMED device step time —
        # the hot-path quantity speculation moves, free of the prefill and
        # host-scheduling wall-clock both engines share (which dwarfs the
        # decode phase on the smoke model and would drown the gate in noise)
        decode_tokens = STEADY_MAX_BATCH * (n_new - 1)
        dec_tps = lambda m: decode_tokens / max(m["decode_ms_total"] / 1e3, 1e-9)
        for _ in range(SPEC_PASSES):
            for mode, eng in engines.items():
                eng.reset_metrics()
                results = eng.run(make())
                outs[mode] = {rid: s.generated for rid, s in results.items()}
                m = eng.metrics()
                prev = stats.get(mode)
                if prev is None or dec_tps(m) > dec_tps(prev):
                    stats[mode] = m
        base, spec = stats["baseline"], stats["speculative"]
        section["workloads"][name] = {
            "accepted_tokens_per_step": spec["accepted_tokens_per_step"],
            "draft_hit_rate": spec["draft_hit_rate"],
            "spec_windows": spec["spec_windows"],
            "spec_rollback_tokens": spec["spec_rollback_tokens"],
            "spec_backoffs": spec["spec_backoffs"],
            "decode_tokens_per_s_baseline": round(dec_tps(base), 1),
            "decode_tokens_per_s_speculative": round(dec_tps(spec), 1),
            "decode_speedup_x": round(dec_tps(spec) / dec_tps(base), 2),
            "tokens_per_s_baseline": base["tokens_per_s"],
            "tokens_per_s_speculative": spec["tokens_per_s"],
            "step_ms_p50_baseline": base["step_ms_p50"],
            "step_ms_p50_speculative": spec["step_ms_p50"],
            "tokens_exact": outs["baseline"] == outs["speculative"],
        }
    rep = section["workloads"]["repetitive"]
    inc = section["workloads"]["incompressible"]
    # the gates CI asserts (recorded here so the report is self-describing)
    section["gates"] = {
        "greedy_token_exact": rep["tokens_exact"] and inc["tokens_exact"],
        "repetitive_accepted_ok": rep["accepted_tokens_per_step"] >= 1.2,
        "repetitive_throughput_above_baseline": (
            rep["decode_tokens_per_s_speculative"]
            > rep["decode_tokens_per_s_baseline"]
        ),
        "incompressible_regression_pct": round(
            100.0 * (1.0 - inc["decode_tokens_per_s_speculative"]
                     / max(inc["decode_tokens_per_s_baseline"], 1e-9)), 1
        ),
    }
    return section


def run_telemetry(model, params, vocab: int, n_new: int) -> dict:
    """The steady-decode trace through a trace=off and a trace=on engine.

    The off/on step_ms_p50 pair is the overhead claim (trace events are
    host-side appends to a preallocated ring — no device work, no extra D2H);
    the trace=on engine's lifecycle trace is exported as Chrome trace-event
    JSON, schema-validated, and summarized as per-name event counts."""
    make = lambda: [
        Request(
                rid=i,
                prompt=np.random.default_rng(90 + i).integers(
                    0, vocab, size=STEADY_PROMPT_LEN
                ).tolist(),
                params=GenerationParams(max_new_tokens=n_new),
            )
        for i in range(STEADY_MAX_BATCH)
    ]
    conf = EngineConfig.sized_for(
        STEADY_PROMPT_LEN + n_new + 1, page_size=STEADY_PAGE_SIZE,
        max_batch=STEADY_MAX_BATCH, multi_step=4,
    )
    stats, outputs, trace_info = {}, {}, {}
    for mode, tr in (("trace_off", False), ("trace_on", True)):
        eng = ServeEngine(model, params, dataclasses.replace(conf, trace=tr))
        eng.run(make())  # rehearsal: compile, warm pools
        eng.reset_metrics()  # also clears rehearsal trace events
        results = eng.run(make())
        outputs[mode] = {rid: s.generated for rid, s in results.items()}
        stats[mode] = eng.metrics()
        if tr:
            chrome = eng.trace.to_chrome()
            validate_chrome_trace(chrome)
            TRACE_PATH.parent.mkdir(exist_ok=True)
            eng.trace.export(TRACE_PATH)
            counts = {}
            for ev in eng.trace.events:
                if ev.ph in ("i", "B"):  # count spans once (by their begin)
                    counts[ev.name] = counts.get(ev.name, 0) + 1
            trace_info = {
                "trace_path": str(TRACE_PATH),
                "trace_events": len(chrome["traceEvents"]),
                "events_dropped": eng.trace.dropped,
                "event_counts": counts,
                "validated": True,
            }
    off_p50 = stats["trace_off"]["step_ms_p50"]
    on_p50 = stats["trace_on"]["step_ms_p50"]
    return {
        "prompt_len": STEADY_PROMPT_LEN,
        "new_tokens": n_new,
        "max_batch": STEADY_MAX_BATCH,
        "multi_step": 4,
        "step_ms_p50_trace_off": off_p50,
        "step_ms_p50_trace_on": on_p50,
        "trace_overhead_pct": round(
            100.0 * (on_p50 - off_p50) / max(off_p50, 1e-9), 2
        ),
        "tokens_per_s_trace_off": stats["trace_off"]["tokens_per_s"],
        "tokens_per_s_trace_on": stats["trace_on"]["tokens_per_s"],
        "tokens_exact": outputs["trace_off"] == outputs["trace_on"],
        **trace_info,
    }


def _jit_cache_sizes(eng: ServeEngine) -> dict:
    """Compile-cache entry counts of the engine's jitted steps — the beam
    section pins 'reorders never retrace' on these staying flat."""
    sizes = {}
    for name in ("_step", "_multistep", "_chunk_step", "_row_logprobs",
                 "_sample_row", "_sample_row_masked"):
        fn = getattr(eng, name, None)
        if fn is not None and hasattr(fn, "_cache_size"):
            sizes[name] = fn._cache_size()
    return sizes


def run_parallel_generation(model, params, vocab: int) -> dict:
    """Branch groups as layout forks, measured three ways.

    best_of_n: an n-branch group vs n serial runs — the group's peak pages
    must be ~one prompt plus n decode tails (the fork aliases every prompt
    page), and each branch's tokens must exactly match a serial engine run
    at seed + branch with the same request id (the branch seed law).

    beam: a beam_width-wide search — per-step survivor reordering is a pure
    block-table-row permutation, so the measured run must show reorders > 0
    with ZERO new compile-cache entries (content uploads, never retraces),
    and resubmitting the identical request must reproduce every sequence.

    constrained: grammar-masked sampling through a host-compiled token DFA —
    every output must parse as the JSON the automaton encodes."""
    # --- best-of-n: page sharing + serial exactness --------------------------
    n, plen, n_new = BRANCH_N, BRANCH_PROMPT_LEN, BRANCH_NEW_TOKENS
    conf = EngineConfig.sized_for(
        plen + n_new + 1, page_size=BRANCH_PAGE_SIZE, max_batch=n,
    )
    prompt = np.random.default_rng(21).integers(0, vocab, size=plen).tolist()
    gp = lambda nn, seed: GenerationParams(
        max_new_tokens=n_new, temperature=0.8, top_k=8, seed=seed, n=nn,
    )
    eng = ServeEngine(model, params, conf)
    eng.submit(list(prompt), gp(n, 123), rid=0)
    eng.run()  # rehearsal: compile prefill + decode + fork/patch paths
    eng.reset_metrics()
    h = eng.submit(list(prompt), gp(n, 123), rid=0)
    eng.run()
    m_group = eng.metrics()
    group_tokens = [s.tokens for s in h.sequences]
    # one serial engine, reused across branches (jit caches are per-engine);
    # the branch seed law folds (seed + b, SAME rid), so rid stays 0
    serial = ServeEngine(model, params, conf)
    serial_tokens = []
    for b in range(n):
        hb = serial.submit(list(prompt), gp(1, 123 + b), rid=0)
        serial.run()
        serial_tokens.append(hb.sequences[0].tokens)
    serial.reset_metrics()
    h1 = serial.submit(list(prompt), gp(1, 123), rid=0)
    serial.run()
    peak_n1 = serial.metrics()["peak_pages_in_use"]
    # page accounting: the group shares ceil(plen / page) prompt pages once and
    # pays a private decode tail per branch; the gate bounds the PROMPT-KV cost
    prompt_pages = -(-plen // BRANCH_PAGE_SIZE)
    tail_pages = -(-(n_new + plen % BRANCH_PAGE_SIZE) // BRANCH_PAGE_SIZE)
    peak_n8 = m_group["peak_pages_in_use"]
    prompt_pages_ratio = (peak_n8 - n * tail_pages) / max(prompt_pages, 1)
    best_of_n = {
        "n": n,
        "prompt_len": plen,
        "new_tokens": n_new,
        "page_size": BRANCH_PAGE_SIZE,
        "peak_pages_group": peak_n8,
        "peak_pages_serial_each": peak_n1,
        "peak_pages_serial_total": n * peak_n1,
        "prompt_pages": prompt_pages,
        "tail_pages_per_branch": tail_pages,
        "prompt_pages_ratio": round(prompt_pages_ratio, 3),
        "branch_forks": m_group["branch_forks"],
        "tokens_per_s_group": m_group["tokens_per_s"],
        "tokens_exact_vs_serial": group_tokens == serial_tokens,
    }
    # --- beam search: reorders without copies or recompiles ------------------
    bconf = EngineConfig.sized_for(
        BEAM_PROMPT_LEN + BEAM_NEW_TOKENS + 1, page_size=BRANCH_PAGE_SIZE,
        max_batch=BEAM_WIDTH, max_beam_width=BEAM_WIDTH,
    )
    bprompt = np.random.default_rng(22).integers(
        0, vocab, size=BEAM_PROMPT_LEN
    ).tolist()
    bp = GenerationParams(max_new_tokens=BEAM_NEW_TOKENS, beam_width=BEAM_WIDTH, n=2)
    beng = ServeEngine(model, params, bconf)
    beng.submit(list(bprompt), bp, rid=0)
    beng.run()  # rehearsal compiles the whole beam path, reorders included
    beng.reset_metrics()
    sizes_before = _jit_cache_sizes(beng)
    hb = beng.submit(list(bprompt), bp, rid=0)
    beng.run()
    m_beam = beng.metrics()
    new_compiles = sum(
        _jit_cache_sizes(beng)[k] - v for k, v in sizes_before.items()
    )
    beam_seqs = [(s.tokens, s.cumulative_logprob) for s in hb.sequences]
    rerun = ServeEngine(model, params, bconf)
    hr = rerun.submit(list(bprompt), bp, rid=0)
    rerun.run()
    beam = {
        "beam_width": BEAM_WIDTH,
        "n_returned": len(beam_seqs),
        "prompt_len": BEAM_PROMPT_LEN,
        "new_tokens": BEAM_NEW_TOKENS,
        "beam_reorders": m_beam["beam_reorders"],
        "cow_copies": m_beam["cow_copies"],
        "new_compiles_in_measured_run": new_compiles,
        "tokens_per_s": m_beam["tokens_per_s"],
        "best_cumulative_logprob": round(beam_seqs[0][1], 4),
        "deterministic": [
            (s.tokens, s.cumulative_logprob) for s in hr.sequences
        ] == beam_seqs,
    }
    # --- constrained decoding: every output parses ---------------------------
    charmap = {ch: i for i, ch in enumerate(JSON_ARRAY_CHARS)}
    eos = len(JSON_ARRAY_CHARS)
    dfa = fixed_json_array_dfa(charmap, eos, vocab, n_items=3)
    gconf = EngineConfig.sized_for(
        8 + GRAMMAR_NEW_TOKENS + 1, page_size=BRANCH_PAGE_SIZE,
        max_batch=GRAMMAR_N_REQUESTS, grammar_states=dfa.n_states,
    )
    geng = ServeEngine(model, params, gconf)
    grng = np.random.default_rng(23)
    submit_all = lambda: [
        geng.submit(
            grng.integers(0, vocab, size=5).tolist(),
            GenerationParams(
                max_new_tokens=GRAMMAR_NEW_TOKENS, temperature=0.9,
                seed=i, eos_id=eos, grammar=dfa,
            ),
            rid=i,
        )
        for i in range(GRAMMAR_N_REQUESTS)
    ]
    submit_all()
    geng.run()  # rehearsal: compile the masked fused step
    geng.reset_metrics()
    handles = submit_all()
    geng.run()
    inv = {i: ch for ch, i in charmap.items()}
    texts, n_valid = [], 0
    for hg in handles:
        seq = hg.sequences[0]
        text = "".join(inv[t] for t in seq.tokens if t != eos)
        texts.append(text)
        try:
            val = json.loads(text)
            n_valid += isinstance(val, list)
        except ValueError:
            pass
    constrained = {
        "n_requests": GRAMMAR_N_REQUESTS,
        "grammar": f"fixed_json_array(n_items=3), {dfa.n_states} states",
        "outputs": texts,
        "valid_json_frac": n_valid / GRAMMAR_N_REQUESTS,
        "tokens_per_s": geng.metrics()["tokens_per_s"],
    }
    return {"best_of_n": best_of_n, "beam": beam, "constrained": constrained}


def run(out_path: Path = None, smoke: bool = False, kv_dtype: str = "all") -> dict:
    if out_path is None:
        out_path = SMOKE_OUT_PATH if smoke else OUT_PATH
    cfg = bench_config(smoke)
    model = Model(cfg)
    params = model.init_params(jax.random.key(0))
    points = POINTS[:1] if smoke else POINTS
    n_requests = 4 if smoke else N_REQUESTS
    max_new = 4 if smoke else MAX_NEW_TOKENS
    shared_n = 4 if smoke else SHARED_N_REQUESTS
    report = {
        "schema_version": SCHEMA_VERSION,
        "model": cfg.name,
        "smoke": smoke,
        "points": [],
    }
    for max_batch, page_size in points:
        # rehearsal on the same engine: compile every prefill bucket + the decode
        # step for these shapes (jit caches are per-engine), then reset and
        # measure. Rehearsal prompts use DISJOINT token ranges: page-aligned
        # prefixes of each other would hit the prefix index and compile only the
        # sliced (shared-tail) pack shapes, leaving the full-write shapes of the
        # measured no-share trace to compile inside the timed region
        eng = engine_for(model, params, max_batch, page_size, max_new)
        eng.run([
            Request(
                    rid=i,
                    prompt=list(range(1 + 100 * i, 1 + 100 * i + L)),
                    params=GenerationParams(max_new_tokens=2),
                )
            for i, L in enumerate(PROMPT_BUCKETS)
        ])
        eng.reset_metrics()
        rng = np.random.default_rng(0)
        eng.run(make_requests(rng, cfg.vocab, n_requests, max_new))
        m = eng.metrics()
        point = {"max_batch": max_batch, "page_size": page_size, **m}
        report["points"].append(point)
        print(
            f"serving/b{max_batch}_ps{page_size},{m['step_ms_p50']*1e3:.2f},"
            f"tokens_per_s={m['tokens_per_s']:.1f} p50={m['latency_s_p50']*1e3:.0f}ms "
            f"p99={m['latency_s_p99']*1e3:.0f}ms ttft_p99={m['ttft_s_p99']*1e3:.0f}ms "
            f"host_overhead_p50={m['host_overhead_ms_p50']:.3f}ms "
            f"preempt={m['preemptions']}"
        )
    sd = run_steady_decode(
        model, params, cfg.vocab,
        n_new=24 if smoke else STEADY_NEW_TOKENS,
        ks=(1, 4) if smoke else MULTI_STEP_KS,
    )
    report["steady_decode"] = sd
    k_last = list(sd["ks"])[-1]
    print(
        "serving/steady_decode,"
        + " ".join(
            f"K={k}:{e['step_ms_p50']:.3f}ms" for k, e in sd["ks"].items()
        )
        + f" (K={k_last} {sd['ks'][k_last]['step_speedup_x_vs_k1']}x vs K=1),"
        f" exact_across_ks={sd['tokens_exact_across_ks']}"
        f" sampled_reproducible={sd['sampled']['reproducible']}"
    )
    sv = run_speculative(
        model, params, cfg.vocab, n_new=48 if smoke else SPEC_NEW_TOKENS,
    )
    report["speculative"] = sv
    rep, inc = sv["workloads"]["repetitive"], sv["workloads"]["incompressible"]
    print(
        f"serving/speculative,K={sv['spec_tokens']}: repetitive "
        f"accepted/step={rep['accepted_tokens_per_step']:.2f} "
        f"hit_rate={rep['draft_hit_rate']:.2f} "
        f"speedup={rep['decode_speedup_x']}x exact={rep['tokens_exact']} | "
        f"incompressible accepted/step={inc['accepted_tokens_per_step']:.2f} "
        f"regression={sv['gates']['incompressible_regression_pct']}% "
        f"exact={inc['tokens_exact']}"
    )
    tel = run_telemetry(model, params, cfg.vocab, n_new=16 if smoke else 32)
    report["telemetry"] = tel
    print(
        f"serving/telemetry,step_p50 {tel['step_ms_p50_trace_on']:.3f}ms on vs "
        f"{tel['step_ms_p50_trace_off']:.3f}ms off "
        f"({tel['trace_overhead_pct']:+.1f}%), "
        f"{tel['trace_events']} trace events -> {tel['trace_path']} "
        f"(validated={tel['validated']}) exact={tel['tokens_exact']}"
    )
    pg = run_parallel_generation(model, params, cfg.vocab)
    report["parallel_generation"] = pg
    bo, bm, cd = pg["best_of_n"], pg["beam"], pg["constrained"]
    print(
        f"serving/parallel_generation,best_of_n n={bo['n']}: peak "
        f"{bo['peak_pages_group']} pages vs {bo['peak_pages_serial_total']} "
        f"serial (prompt_pages_ratio {bo['prompt_pages_ratio']}x, "
        f"exact={bo['tokens_exact_vs_serial']}) | beam w={bm['beam_width']}: "
        f"{bm['beam_reorders']} reorders, {bm['new_compiles_in_measured_run']} "
        f"new compiles, deterministic={bm['deterministic']} | constrained: "
        f"{cd['valid_json_frac']:.0%} valid JSON {cd['outputs']}"
    )
    sp = run_shared_prefix(model, params, cfg.vocab, shared_n, max_new)
    report["shared_prefix"] = sp
    print(
        f"serving/shared_prefix,peak_pages {sp['peak_pages_sharing_on']} vs "
        f"{sp['peak_pages_sharing_off']} (-{sp['peak_pages_saved_pct']}%), "
        f"shared={sp['pages_shared']} cow={sp['cow_copies']} "
        f"exact={sp['tokens_exact']}"
    )
    kv_dtypes = (
        ("f32", "int8", "int4") if kv_dtype == "all"
        else tuple(dict.fromkeys(("f32", kv_dtype)))  # f32 baseline always runs
    )
    qs = run_quantized(model, params, cfg.vocab, shared_n, max_new, kv_dtypes)
    report["quantized"] = qs
    lb = run_long_prompt_burst(
        max_new, n_long=1 if smoke else LONG_N, n_short=3 if smoke else SHORT_N,
    )
    report["long_prompt_burst"] = lb
    hk = run_hierarchical_kv(smoke)
    report["hierarchical_kv"] = hk
    hr, ho, hi = hk["session_resume"], hk["oversubscription"], hk["tier_idle"]
    print(
        f"serving/hierarchical_kv,resume ttft_p50 "
        f"{hr['ttft_s_p50_resume']*1e3:.0f}ms vs "
        f"{hr['ttft_s_p50_recompute']*1e3:.0f}ms recompute "
        f"({hr['resume_ttft_speedup_x']}x, prefetch={hr['prefetch_hits']} "
        f"exact={hr['tokens_exact']}) | {ho['oversubscription_x']}x oversub: "
        f"{ho['tokens_per_s_oversubscribed']:.1f} vs "
        f"{ho['tokens_per_s_unconstrained']:.1f} tok/s "
        f"({ho['throughput_retained_pct']}%), swap_out={ho['swap_out_pages']} "
        f"prefetch={ho['prefetch_hits']} exact={ho['tokens_exact']} | idle "
        f"overhead {hi['idle_overhead_pct']:+.1f}% "
        f"(untouched={hi['tier_untouched']})"
    )
    sk = lb["prefix_compute_skip"]
    print(
        f"serving/long_prompt_burst,ttft_p50 "
        f"{lb['ttft_s_p50_chunked']*1e3:.0f}ms chunked vs "
        f"{lb['ttft_s_p50_monolithic']*1e3:.0f}ms monolithic "
        f"({lb['ttft_p50_speedup_x']}x), p95 {lb['ttft_s_p95_chunked']*1e3:.0f} vs "
        f"{lb['ttft_s_p95_monolithic']*1e3:.0f}ms, exact={lb['tokens_exact']} | "
        f"compute-skip {sk['prefill_tokens_skipped']} tokens "
        f"({sk['prefill_flops_saved_pct']}% of prefill) "
        f"exact_vs_cold={sk['tokens_exact_vs_cold']}"
    )
    for kv, e in qs["dtypes"].items():
        extra = (
            f" capacity_x={e['capacity_x_vs_f32']} "
            f"max_logit_err={e['max_logit_err_vs_f32']:.4f} "
            f"exact={e['tokens_exact_vs_f32']}"
            if kv != "f32" else ""
        )
        print(
            f"serving/quantized_{kv},{e['step_ms_p50']*1e3:.2f},"
            f"peak_pages={e['peak_pages_in_use']} "
            f"tokens_per_s={e['tokens_per_s']:.1f} "
            f"pool_bytes={e['kv_pool_bytes']}{extra}"
        )
    out_path.write_text(json.dumps(report, indent=2))
    print(f"serving suite written to {out_path}")
    return report


if __name__ == "__main__":
    run()
