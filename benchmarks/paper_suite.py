"""The paper's benchmark suite, one entry per figure/table.

All "overhead" comparisons run COMPILED code on this host's CPU (XLA:CPU): the
mdspan-mediated computation vs the hand-written raw-jnp one. The paper's claim is
that the abstraction adds nothing once the optimizer runs — here that is testable
*exactly* (same compiler, same machine) and additionally *structurally*: we diff
the optimized HLO op histograms. Pallas-kernel versions of the same benchmarks
are validated separately for correctness (tests/) and characterized by the
roofline (TPU is the target, not this CPU).

Figures reproduced:
  Fig 3/4  Sum3D / Stencil3D / TinyMatrixSum overhead, mdspan vs raw
  Fig 5    TinyMatrixSum static vs dynamic inner extents
  Fig 6    MatVec layout_right vs layout_left (CPU measured + TPU roofline model)
  Fig 7/8  Subspan3D: subspan-composed traversal vs direct indexing
  (extra)  QuantizedAccessor scale(): bytes touched nblocks vs span (negative
           overhead — the accessor-aware fast path)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    MdSpan,
    QuantizedAccessor,
    all_,
    submdspan,
)
from repro.core import algorithms as alg
from repro.kernels import ref

from .common import hlo_ops, time_fn

ROWS = []


def row(name: str, us: float, derived: str):
    ROWS.append((name, us, derived))
    print(f"{name},{us:.2f},{derived}")


# ------------------------------------------------------------------------------
# Fig 3/4: overhead of the mdspan abstraction
# ------------------------------------------------------------------------------
def bench_overhead_suite(n=96, j=96, k=96):
    key = jax.random.key(0)
    x = jax.random.normal(key, (n, j, k), jnp.float32)

    raw_sum = jax.jit(lambda x: jnp.sum(x))
    md_sum = jax.jit(lambda x: alg.reduce_sum(MdSpan.from_dense(x)))
    t_raw = time_fn(raw_sum, x)
    t_md = time_fn(md_sum, x)
    row("sum3d_raw", t_raw, "")
    row("sum3d_mdspan", t_md, f"overhead={100*(t_md/t_raw-1):+.1f}%")
    assert hlo_ops(lambda x: jnp.sum(x), x) == hlo_ops(
        lambda x: alg.reduce_sum(MdSpan.from_dense(x)), x
    ), "sum3d HLO must be identical"
    row("sum3d_hlo_identical", 0.0, "True")

    raw_st = jax.jit(ref.stencil3d)
    md_st = jax.jit(lambda x: ref.stencil3d(MdSpan.from_dense(x).to_dense()))
    t_raw = time_fn(raw_st, x)
    t_md = time_fn(md_st, x)
    row("stencil3d_raw", t_raw, "")
    row("stencil3d_mdspan", t_md, f"overhead={100*(t_md/t_raw-1):+.1f}%")

    o = jax.random.normal(key, (100_000, 3, 3))
    s = jax.random.normal(jax.random.key(1), (100_000, 3, 3))
    raw_tm = jax.jit(lambda o, s: o + s)
    md_tm = jax.jit(
        lambda o, s: (MdSpan.from_dense(o).to_dense() + MdSpan.from_dense(s).to_dense())
    )
    t_raw = time_fn(raw_tm, o, s)
    t_md = time_fn(md_tm, o, s)
    row("tinymatsum_raw", t_raw, "")
    row("tinymatsum_mdspan", t_md, f"overhead={100*(t_md/t_raw-1):+.1f}%")


# ------------------------------------------------------------------------------
# Fig 5: static vs dynamic extents (TinyMatrixSum)
# ------------------------------------------------------------------------------
def bench_static_vs_dynamic(n=200_000):
    key = jax.random.key(0)
    o = jax.random.normal(key, (n, 3, 3))
    s = jax.random.normal(jax.random.key(1), (n, 3, 3))

    # static: (3,3) baked into the compiled program — dense vector add
    static = jax.jit(lambda o, s: o + s)

    # dynamic: compiled for a (jmax,kmax)=(8,8) envelope, true extents at runtime
    # (the un-specializable path: padded data + masked lanes)
    from repro.kernels.common import pad_to

    # envelope (4,4): the smallest sublane-aligned bound over the true (3,3) —
    # what a kernel compiled for runtime extents must provision
    op = pad_to(o, (n, 4, 4))
    sp = pad_to(s, (n, 4, 4))

    def dynamic(o, s, jk):
        jj = jax.lax.broadcasted_iota(jnp.int32, o.shape, 1)
        kk = jax.lax.broadcasted_iota(jnp.int32, o.shape, 2)
        live = (jj < jk[0]) & (kk < jk[1])
        return jnp.where(live, o + s, o)

    dyn = jax.jit(dynamic)
    jk = jnp.array([3, 3], jnp.int32)
    t_static = time_fn(static, o, s)
    t_dyn = time_fn(dyn, op, sp, jk)
    row("tinymatsum_static_extents", t_static, "")
    row(
        "tinymatsum_dynamic_extents",
        t_dyn,
        f"static_speedup={t_dyn/t_static:.2f}x (paper Fig5: ~2x)",
    )


# ------------------------------------------------------------------------------
# Fig 6: MatVec layout comparison
# ------------------------------------------------------------------------------
def bench_matvec_layouts(i=2048, j=2048):
    key = jax.random.key(0)
    a = jax.random.normal(key, (i, j))
    at = jnp.asarray(np.asfortranarray(np.array(a)))  # column-major storage
    x = jax.random.normal(jax.random.key(1), (j,))

    # same ALGORITHM, layout picked by the mdspan type (dispatch in kernels/ops.py)
    right = jax.jit(lambda a, x: a @ x)
    # honest left-layout schedule: contraction over the slow axis of the stored
    # buffer (XLA gets the transposed buffer and must reduce over rows)
    left = jax.jit(lambda at_buf, x: jnp.einsum("ji,j->i", at_buf, x))
    t_right = time_fn(right, a, x)
    t_left = time_fn(left, at.T.reshape(j, i), x)
    row("matvec_layout_right", t_right, "")
    row(
        "matvec_layout_left",
        t_left,
        f"right/left={t_left/max(t_right,1e-9):.2f}x (paper Fig6 CPU: 3-7x)",
    )
    # TPU roofline model (target hardware; see DESIGN.md §2): layout_right keeps
    # the contraction on the 128-lane axis — memory-bound at 819 GB/s. layout_left
    # either reduces across sublanes (8x lane waste) or transposes in VMEM.
    bytes_a = i * j * 4
    t_right_model = bytes_a / 819e9
    t_left_model = bytes_a / 819e9 * 8  # sublane-reduction schedule
    row(
        "matvec_tpu_roofline_model",
        t_right_model * 1e6,
        f"left/right={t_left_model/t_right_model:.0f}x (paper Fig6 GPU: ~10x)",
    )


# ------------------------------------------------------------------------------
# Fig 7/8: subspan overhead
# ------------------------------------------------------------------------------
def bench_subspan(n=64, j=64, k=64):
    x = jax.random.normal(jax.random.key(0), (n, j, k))

    def raw(x):
        return jnp.sum(x)

    def via_subspan(x):
        span = MdSpan.from_dense(x)
        total = jnp.float32(0)
        for i in range(span.extent(0)):
            sub = submdspan(span, i, all_, all_)
            total = total + jnp.sum(sub.to_dense())
        return total

    t_raw = time_fn(jax.jit(raw), x)
    t_sub = time_fn(jax.jit(via_subspan), x)
    row("subspan3d_raw", t_raw, "")
    row("subspan3d_mdspan", t_sub, f"overhead={100*(t_sub/t_raw-1):+.1f}%")
    np.testing.assert_allclose(float(raw(x)), float(via_subspan(x)), rtol=1e-2, atol=1e-2)  # reduction-tree order


# ------------------------------------------------------------------------------
# extra: accessor-aware scale on quantized storage (negative overhead)
# ------------------------------------------------------------------------------
def bench_quantized_scale(rows=512, cols=4096):
    qa = QuantizedAccessor(jnp.float32, bits=8, block=64)
    x = jax.random.normal(jax.random.key(0), (rows, cols))
    m = MdSpan.from_dense(x, accessor=qa)
    dense = jax.jit(lambda x: x * 2.0)
    quant = jax.jit(lambda bufs: alg.scale(MdSpan(bufs, m.layout, qa), 2.0).buffers)
    t_dense = time_fn(dense, x)
    t_quant = time_fn(quant, m.buffers)
    touched_dense = rows * cols * 4
    touched_quant = rows * cols // 64 * 4
    row("scale_dense", t_dense, f"bytes={touched_dense}")
    row(
        "scale_quantized_accessor",
        t_quant,
        f"bytes={touched_quant} ({touched_dense//touched_quant}x fewer), "
        f"speedup={t_dense/max(t_quant,1e-9):.1f}x",
    )


def run_all():
    print("name,us_per_call,derived")
    bench_overhead_suite()
    bench_static_vs_dynamic()
    bench_matvec_layouts()
    bench_subspan()
    bench_quantized_scale()
    return ROWS
