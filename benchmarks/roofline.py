"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch × shape) on the single-pod mesh:
  compute term    = HLO_FLOPs / (chips × 197 TFLOP/s)
  memory term     = HLO_bytes / (chips × 819 GB/s)
  collective term = collective_moved_bytes / (chips × 50 GB/s/link)

HLO_FLOPs / bytes / collective bytes come from the depth-probe extrapolation
(launch/dryrun.py: cost_analysis counts while-loop bodies once; the probes fit
metric(L) = a + L·b on unrolled shallow compiles). All probe numbers are
PER-DEVICE (the compiled module is the post-SPMD per-device program), so no
division by chip count is applied to them — the hardware denominator is per-chip.

MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) per step (train: includes
fwd+bwd; decode/prefill: 2·N·D per token forward).

Roofline placement (the serving perf matrix's anchor)
-----------------------------------------------------
Paged decode is BANDWIDTH-bound: per generated token every live KV page of
every sequence streams through the attention kernel once (K and V), while the
matching compute is a handful of dot products per page — arithmetic intensity
well below any machine's balance point. That makes the roof *computable*:

    roof_s      = analytic_bytes / machine_bandwidth
    attainment  = (bytes_per_step / measured_step_s) / machine_bandwidth

``paged_decode_analytic_bytes`` supplies the numerator from the layout's own
page math (whole live pages, dtype-priced payload + quant scales), and
core.instrument's CountingAccessor MEASURES the same number through the flat
accessor — two independent derivations the tests pin within 10% of each other
for all three kv dtypes. ``machine_bandwidth`` is not a datasheet constant:
``measure_machine_bandwidth`` runs a STREAM-style triad/copy microbenchmark
once per host and caches the result (attainment against a paper number is
fiction on a shared CI box). Placement is then interpreted as:

  * attainment > 1.0  — a measurement bug, always (you cannot beat the
    machine); benchmarks/perf_matrix.py fails the run loudly;
  * attainment near the per-dtype floor — healthy; quantized pools sit lower
    than f32 because their scale reads and dequant math dilute pure streaming;
  * attainment well below floor — the schedule left bandwidth on the table
    (bad block shape, gather overhead): exactly what the autotuner sweeps away
    and what a per-cell ratchet catches when a refactor regresses it.
"""
from __future__ import annotations

import json
import socket
import time
from pathlib import Path

import numpy as np

from repro.configs.shapes import SHAPES

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s / chip
ICI_BW = 50e9  # B/s / link

ARTI = Path("artifacts/dryrun")

# element bytes per KV pool entry, by EngineConfig.kv_dtype; quantized pools
# add one f32 scale per (page, kv head) on top of the payload
_KV_ELT_BYTES = {"f32": 4.0, "int8": 1.0, "int4": 0.5}


def paged_decode_analytic_bytes(
    context_lens,
    *,
    page_size: int,
    n_kv_heads: int,
    head_dim: int,
    kv_dtype: str = "f32",
) -> int:
    """Analytic KV-pool bytes one paged-decode step must move.

    The kernel DMAs whole pages and skips pages wholly past a sequence's
    length (`pl.when(j * page_size < seq_len)`), so per sequence the traffic
    is ceil(len / page_size) pages × page_size × Hkv × D elements, twice (K
    and V). Quantized pools move intN payload plus one f32 scale per (page,
    head) per pool. This is the model core.instrument's CountingAccessor
    must agree with (tests pin ±10% for f32, int8 AND int4): the counted twin
    reads the same live pages through the flat-codomain accessor, so the two
    derive the same traffic from opposite ends — formula vs measurement.
    """
    if kv_dtype not in _KV_ELT_BYTES:
        raise ValueError(f"kv_dtype {kv_dtype!r} not in {sorted(_KV_ELT_BYTES)}")
    elt = _KV_ELT_BYTES[kv_dtype]
    total = 0.0
    for n_tok in context_lens:
        n_tok = int(n_tok)
        if n_tok <= 0:
            continue
        live_pages = -(-n_tok // page_size)
        payload = live_pages * page_size * n_kv_heads * head_dim * elt
        scales = (
            live_pages * n_kv_heads * 4 if kv_dtype in ("int8", "int4") else 0
        )
        total += 2 * (payload + scales)  # K pool + V pool
    return int(total)


# -------------------------------------------------------------------------------
# machine bandwidth (STREAM-style, measured once per host, cached) + attainment
# -------------------------------------------------------------------------------
BW_CACHE_PATH = Path("artifacts/machine_bandwidth.json")
_STREAM_ELEMS = 8 * 1024 * 1024  # 64 MB per f64 array — well past any LLC
_STREAM_REPS = 5


def _stream_gbs() -> float:
    """Best-of sustained memory bandwidth (bytes/s) from STREAM copy + triad.

    numpy's vectorized kernels stream arrays exactly like STREAM's C loops;
    copy moves 2 arrays per pass, triad 3. Best-of across repetitions is the
    STREAM convention — the quantity of interest is the machine's capability,
    not the noise floor of a shared box.
    """
    n = _STREAM_ELEMS
    a = np.random.default_rng(0).standard_normal(n)
    b = np.empty_like(a)
    c = np.empty_like(a)
    best = 0.0
    for _ in range(_STREAM_REPS):
        t0 = time.perf_counter()
        np.copyto(b, a)                       # copy: 2 arrays
        t1 = time.perf_counter()
        np.multiply(a, 3.0, out=c)
        np.add(c, b, out=c)                   # triad: 3 arrays (+1 temp read)
        t2 = time.perf_counter()
        best = max(best, 2 * n * 8 / (t1 - t0), 3 * n * 8 / (t2 - t1))
    return best


def measure_machine_bandwidth(cache_path: Path | str | None = None,
                              refresh: bool = False) -> float:
    """Measured machine bandwidth (bytes/s), calibrated ONCE per host + cached.

    The perf matrix divides every cell's achieved GB/s by this number; caching
    per hostname keeps a committed baseline meaningful across runs on the same
    machine while forcing recalibration the first time a different box runs
    the suite. ``refresh=True`` re-measures unconditionally.
    """
    path = Path(cache_path) if cache_path is not None else BW_CACHE_PATH
    host = socket.gethostname()
    cache = {}
    try:
        cache = json.loads(path.read_text())
    except (OSError, ValueError):
        pass
    if not refresh and isinstance(cache.get(host), (int, float)) and cache[host] > 0:
        return float(cache[host])
    bw = _stream_gbs()
    cache[host] = bw
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(cache, indent=2) + "\n")
    return bw


def attainment(bytes_per_step: float, step_s: float, machine_bw: float) -> float:
    """Fraction of the measured machine bandwidth a cell achieved:
    (bytes moved / wall time) / machine_bw. > 1.0 is a measurement bug by
    construction — the matrix harness fails such cells loudly."""
    if step_s <= 0 or machine_bw <= 0:
        return 0.0
    return (bytes_per_step / step_s) / machine_bw


def model_flops(rec: dict, shape) -> float:
    """Analytic 'useful' flops per step per CHIP."""
    n_active = rec["params_active"]
    world = rec["world"]
    if shape.kind == "train":
        tokens = shape.batch * shape.seq
        total = 6 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.batch * shape.seq
        total = 2 * n_active * tokens
    else:  # decode: one token per sequence
        total = 2 * n_active * shape.batch
    return total / world


def load_cells(mesh: str = "pod16x16", tag: str = ""):
    cells = []
    for f in sorted(ARTI.glob(f"*__{mesh}{'__' + tag if tag else ''}.json")):
        r = json.loads(Path(f).read_text())
        if tag == "" and r.get("tag"):
            continue
        if not r.get("ok") or r.get("skipped"):
            continue
        cells.append(r)
    return cells


def analyze(rec: dict) -> dict:
    shape = SHAPES[rec["shape"]]
    e = rec["extrapolated"]
    flops = e["flops_per_device"]
    byts = e["bytes_per_device"]
    coll = e["collective_moved_bytes_per_device"]
    t_c = flops / PEAK_FLOPS
    t_m = byts / HBM_BW
    t_x = coll / ICI_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    dom = max(terms, key=terms.get)
    mf = model_flops(rec, shape)
    step = max(t_c, t_m, t_x)  # no-overlap bound
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec["mesh"],
        "tag": rec.get("tag", ""),
        "compute_s": t_c,
        "memory_s": t_m,
        "collective_s": t_x,
        "dominant": dom,
        "model_flops_per_chip": mf,
        "useful_ratio": mf / flops if flops else 0.0,
        "roofline_fraction": (mf / PEAK_FLOPS) / step if step else 0.0,
        "step_bound_s": step,
        "mem_temp_gb": rec.get("memory", {}).get("temp_size_in_bytes", 0) / 1e9,
    }


def table(mesh: str = "pod16x16", tag: str = "") -> list:
    return [analyze(r) for r in load_cells(mesh, tag)]


def render_markdown(rows) -> str:
    hdr = (
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "useful FLOP ratio | roofline frac | bound s/step |\n|---|---|---|---|---|---|---|---|---|\n"
    )
    out = [hdr]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | {r['memory_s']:.3e} "
            f"| {r['collective_s']:.3e} | **{r['dominant']}** | {r['useful_ratio']:.2f} "
            f"| {r['roofline_fraction']:.2f} | {r['step_bound_s']:.3e} |\n"
        )
    return "".join(out)


def run(print_csv: bool = True):
    """Print baseline (artifacts/dryrun) and, when present, the optimized sweep
    (artifacts/dryrun_opt — §Perf code paths) side by side."""
    global ARTI
    out_rows = {}
    for label, d in (("base", Path("artifacts/dryrun")), ("opt", Path("artifacts/dryrun_opt"))):
        if not d.exists() or not list(d.glob("*.json")):
            continue
        ARTI = d
        rows = table()
        out_rows[label] = rows
        if print_csv:
            for r in rows:
                us = r["step_bound_s"] * 1e6
                print(
                    f"roofline[{label}]_{r['arch']}_{r['shape']},{us:.1f},"
                    f"dominant={r['dominant']};frac={r['roofline_fraction']:.2f};"
                    f"useful={r['useful_ratio']:.2f}"
                )
    if print_csv and len(out_rows) == 2:
        base = {(r["arch"], r["shape"]): r for r in out_rows["base"]}
        for r in out_rows["opt"]:
            b = base.get((r["arch"], r["shape"]))
            if b and b["step_bound_s"] / max(r["step_bound_s"], 1e-12) >= 1.05:
                print(
                    f"roofline_speedup_{r['arch']}_{r['shape']},"
                    f"{r['step_bound_s']*1e6:.1f},"
                    f"{b['step_bound_s']/r['step_bound_s']:.1f}x_vs_baseline"
                )
    Path("artifacts").mkdir(exist_ok=True)
    Path("artifacts/roofline.json").write_text(json.dumps(out_rows, indent=1))
    return out_rows
