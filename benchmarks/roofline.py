"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch × shape) on the single-pod mesh:
  compute term    = HLO_FLOPs / (chips × 197 TFLOP/s)
  memory term     = HLO_bytes / (chips × 819 GB/s)
  collective term = collective_moved_bytes / (chips × 50 GB/s/link)

HLO_FLOPs / bytes / collective bytes come from the depth-probe extrapolation
(launch/dryrun.py: cost_analysis counts while-loop bodies once; the probes fit
metric(L) = a + L·b on unrolled shallow compiles). All probe numbers are
PER-DEVICE (the compiled module is the post-SPMD per-device program), so no
division by chip count is applied to them — the hardware denominator is per-chip.

MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) per step (train: includes
fwd+bwd; decode/prefill: 2·N·D per token forward).
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.configs.shapes import SHAPES

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s / chip
ICI_BW = 50e9  # B/s / link

ARTI = Path("artifacts/dryrun")

# element bytes per KV pool entry, by EngineConfig.kv_dtype; quantized pools
# add one f32 scale per (page, kv head) on top of the payload
_KV_ELT_BYTES = {"f32": 4.0, "int8": 1.0, "int4": 0.5}


def paged_decode_analytic_bytes(
    context_lens,
    *,
    page_size: int,
    n_kv_heads: int,
    head_dim: int,
    kv_dtype: str = "f32",
) -> int:
    """Analytic KV-pool bytes one paged-decode step must move.

    The kernel DMAs whole pages and skips pages wholly past a sequence's
    length (`pl.when(j * page_size < seq_len)`), so per sequence the traffic
    is ceil(len / page_size) pages × page_size × Hkv × D elements, twice (K
    and V). Quantized pools move intN payload plus one f32 scale per (page,
    head) per pool. This is the model core.instrument's CountingAccessor
    must agree with (tests pin ±10% for f32 and int8): the counted twin reads
    the same live pages through the flat-codomain accessor, so the two derive
    the same traffic from opposite ends — formula vs measurement.
    """
    if kv_dtype not in _KV_ELT_BYTES:
        raise ValueError(f"kv_dtype {kv_dtype!r} not in {sorted(_KV_ELT_BYTES)}")
    elt = _KV_ELT_BYTES[kv_dtype]
    total = 0.0
    for n_tok in context_lens:
        n_tok = int(n_tok)
        if n_tok <= 0:
            continue
        live_pages = -(-n_tok // page_size)
        payload = live_pages * page_size * n_kv_heads * head_dim * elt
        scales = (
            live_pages * n_kv_heads * 4 if kv_dtype in ("int8", "int4") else 0
        )
        total += 2 * (payload + scales)  # K pool + V pool
    return int(total)


def model_flops(rec: dict, shape) -> float:
    """Analytic 'useful' flops per step per CHIP."""
    n_active = rec["params_active"]
    world = rec["world"]
    if shape.kind == "train":
        tokens = shape.batch * shape.seq
        total = 6 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.batch * shape.seq
        total = 2 * n_active * tokens
    else:  # decode: one token per sequence
        total = 2 * n_active * shape.batch
    return total / world


def load_cells(mesh: str = "pod16x16", tag: str = ""):
    cells = []
    for f in sorted(ARTI.glob(f"*__{mesh}{'__' + tag if tag else ''}.json")):
        r = json.loads(Path(f).read_text())
        if tag == "" and r.get("tag"):
            continue
        if not r.get("ok") or r.get("skipped"):
            continue
        cells.append(r)
    return cells


def analyze(rec: dict) -> dict:
    shape = SHAPES[rec["shape"]]
    e = rec["extrapolated"]
    flops = e["flops_per_device"]
    byts = e["bytes_per_device"]
    coll = e["collective_moved_bytes_per_device"]
    t_c = flops / PEAK_FLOPS
    t_m = byts / HBM_BW
    t_x = coll / ICI_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    dom = max(terms, key=terms.get)
    mf = model_flops(rec, shape)
    step = max(t_c, t_m, t_x)  # no-overlap bound
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec["mesh"],
        "tag": rec.get("tag", ""),
        "compute_s": t_c,
        "memory_s": t_m,
        "collective_s": t_x,
        "dominant": dom,
        "model_flops_per_chip": mf,
        "useful_ratio": mf / flops if flops else 0.0,
        "roofline_fraction": (mf / PEAK_FLOPS) / step if step else 0.0,
        "step_bound_s": step,
        "mem_temp_gb": rec.get("memory", {}).get("temp_size_in_bytes", 0) / 1e9,
    }


def table(mesh: str = "pod16x16", tag: str = "") -> list:
    return [analyze(r) for r in load_cells(mesh, tag)]


def render_markdown(rows) -> str:
    hdr = (
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "useful FLOP ratio | roofline frac | bound s/step |\n|---|---|---|---|---|---|---|---|---|\n"
    )
    out = [hdr]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | {r['memory_s']:.3e} "
            f"| {r['collective_s']:.3e} | **{r['dominant']}** | {r['useful_ratio']:.2f} "
            f"| {r['roofline_fraction']:.2f} | {r['step_bound_s']:.3e} |\n"
        )
    return "".join(out)


def run(print_csv: bool = True):
    """Print baseline (artifacts/dryrun) and, when present, the optimized sweep
    (artifacts/dryrun_opt — §Perf code paths) side by side."""
    global ARTI
    out_rows = {}
    for label, d in (("base", Path("artifacts/dryrun")), ("opt", Path("artifacts/dryrun_opt"))):
        if not d.exists() or not list(d.glob("*.json")):
            continue
        ARTI = d
        rows = table()
        out_rows[label] = rows
        if print_csv:
            for r in rows:
                us = r["step_bound_s"] * 1e6
                print(
                    f"roofline[{label}]_{r['arch']}_{r['shape']},{us:.1f},"
                    f"dominant={r['dominant']};frac={r['roofline_fraction']:.2f};"
                    f"useful={r['useful_ratio']:.2f}"
                )
    if print_csv and len(out_rows) == 2:
        base = {(r["arch"], r["shape"]): r for r in out_rows["base"]}
        for r in out_rows["opt"]:
            b = base.get((r["arch"], r["shape"]))
            if b and b["step_bound_s"] / max(r["step_bound_s"], 1e-12) >= 1.05:
                print(
                    f"roofline_speedup_{r['arch']}_{r['shape']},"
                    f"{r['step_bound_s']*1e6:.1f},"
                    f"{b['step_bound_s']/r['step_bound_s']:.1f}x_vs_baseline"
                )
    Path("artifacts").mkdir(exist_ok=True)
    Path("artifacts/roofline.json").write_text(json.dumps(out_rows, indent=1))
    return out_rows
