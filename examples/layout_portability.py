"""Layout portability: the SAME matvec algorithm retargeted by swapping the layout
in the mdspan "type" — the paper's Fig. 6 experiment (and its cluster-scale
sibling: retargeting a model's parallelism by swapping one ShardingRules table).

Run: PYTHONPATH=src python examples/layout_portability.py
"""
import time

import jax
import jax.numpy as jnp

from repro.core import Extents, LayoutLeft, LayoutRight, MdSpan
from repro.kernels import ops


def timed(f, *a):
    f(*a).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(10):
        out = f(*a)
    out.block_until_ready()
    return (time.perf_counter() - t0) / 10 * 1e6


def main():
    i, j = 2048, 2048
    a = jax.random.normal(jax.random.key(0), (i, j))
    x = jax.random.normal(jax.random.key(1), (j,))

    # one algorithm, two layouts — dispatch happens on the mdspan's layout type
    m_right = MdSpan.from_dense(a, layout=LayoutRight(Extents.fully_dynamic(i, j)))
    m_left = MdSpan.from_dense(a, layout=LayoutLeft(Extents.fully_dynamic(i, j)))

    f_right = jax.jit(lambda buf, x: ops.matvec(m_right.with_buffers(buf), x, impl="jnp"))
    f_left = jax.jit(lambda buf, x: ops.matvec(m_left.with_buffers(buf), x, impl="jnp"))

    y1 = f_right(m_right.buffers, x)
    y2 = f_left(m_left.buffers, x)
    assert jnp.allclose(y1, y2, rtol=1e-4), "same semantics regardless of layout"

    t_r = timed(f_right, m_right.buffers, x)
    t_l = timed(f_left, m_left.buffers, x)
    print(f"matvec layout_right: {t_r:8.1f} us")
    print(f"matvec layout_left:  {t_l:8.1f} us")
    print("identical results; the layout lives in the TYPE, the algorithm never changed.")

    # cluster-scale version of the same idea: one ShardingRules edit retargets
    # a model's parallelism (see src/repro/launch/sharding.py and DESIGN.md §3)
    from repro.launch.sharding import serve_rules, train_rules
    from repro.models import get_config

    cfg = get_config("llama3.2-1b")
    print("\ntrain-time layout of w_gate (embed,ffn):",
          train_rules(cfg).rules["embed"], "x", train_rules(cfg).rules["ffn"])
    print("serve-time layout of w_gate (embed,ffn):",
          serve_rules(cfg).rules["embed"], "x", serve_rules(cfg).rules["ffn"])


if __name__ == "__main__":
    main()
