"""Quantized serving: int8 (QuantizedAccessor) weights, prefill + batched greedy
decode, vs the bf16 model — the paper's accessor customization end-to-end.

Run: PYTHONPATH=src python examples/serve_quant.py --tokens 12
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import build_model, get_config


def generate(model, params, prompt, n_tokens, max_len):
    logits, caches = model.prefill(params, prompt, max_len=max_len)
    tok = jnp.argmax(logits[:, 0], -1)
    out = [tok]
    step = jax.jit(model.decode_step, donate_argnums=(1,))
    pos0 = prompt.shape[1]
    t0 = time.perf_counter()
    for g in range(n_tokens - 1):
        logits, caches = step(params, caches, tok, pos0 + g)
        tok = jnp.argmax(logits, -1)
        out.append(tok)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    return jnp.stack(out, 1), dt / max(n_tokens - 1, 1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--tokens", type=int, default=12)
    ap.add_argument("--batch", type=int, default=2)
    args = ap.parse_args()

    cfg = dataclasses.replace(get_config(args.arch, smoke=True), dtype="float32")
    dense = build_model(cfg)
    quant = build_model(cfg, quantized=True)

    key = jax.random.key(0)
    dparams = dense.init_params(key)
    qparams = quant.init_params(key)  # same key -> quantized version of same weights

    prompt = jax.random.randint(jax.random.key(1), (args.batch, 8), 0, cfg.vocab)
    max_len = prompt.shape[1] + args.tokens + 1

    d_out, d_lat = generate(dense, dparams, prompt, args.tokens, max_len)
    q_out, q_lat = generate(quant, qparams, prompt, args.tokens, max_len)

    agree = float(jnp.mean((d_out == q_out).astype(jnp.float32)))
    print(f"bf16/f32 model tokens: {np.array(d_out[0])}")
    print(f"int8 accessor tokens:  {np.array(q_out[0])}")
    print(f"greedy agreement: {agree:.0%} (quantization is lossy; divergence is expected "
          f"after a few tokens)")
    print(f"per-token latency: dense {d_lat*1e3:.1f} ms | int8 {q_lat*1e3:.1f} ms (CPU demo; "
          f"the int8 win is HBM bytes on the TPU target — see EXPERIMENTS.md §Perf)")


if __name__ == "__main__":
    main()
