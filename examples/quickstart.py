"""Quickstart: the paper's mdspan API in JAX — every code example from the paper.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp

from repro.core import (
    Extents,
    LayoutLeft,
    LayoutRight,
    LayoutSymmetricPacked,
    LayoutTiledTPU,
    MdSpan,
    QuantizedAccessor,
    all_,
    dynamic_extent,
    mdspan,
    submdspan,
)
from repro.core import algorithms as alg


def main():
    # --- paper §Design: interpret memory as a 20x40 matrix -----------------------
    data = jnp.arange(20 * 40, dtype=jnp.float32)
    my_matrix = mdspan(data, 20, 40)
    print("my_matrix(10, 5) =", float(my_matrix(10, 5)))

    # functional operator(): some_matrix(10, 5) += 3.14
    my_matrix = my_matrix.set((10, 5), my_matrix(10, 5) + 3.14)
    print("after += 3.14   =", float(my_matrix(10, 5)))

    # static + dynamic extents:  mdspan<float, 20, dynamic_extent>(data, 40)
    e = Extents.of(20, dynamic_extent)(40)
    print("extents:", e, "| static_extent(0) =", e.static_extent(0))

    # --- the extent loop from the paper -------------------------------------------
    # for(row...) for(col...) my_mat(row, col) *= 2.0  ==> layout-generic scale()
    doubled = alg.scale(my_matrix, 2.0)
    print("scaled(0, 38) =", float(doubled(0, 38)))

    # --- subspan: 3x4x5x20 tensor, subspan(t, 2, all, pair{2,4}, 0) -> 4x2 --------
    my_tens = mdspan(jnp.arange(3 * 4 * 5 * 20, dtype=jnp.float32), 3, 4, 5, 20)
    my_mat = submdspan(my_tens, 2, all_, (2, 4), 0)
    print("subspan shape:", my_mat.shape, "| my_mat(1, 1) =", float(my_mat(1, 1)))

    # --- layouts: same data, different mappings ------------------------------------
    x = jnp.arange(6.0).reshape(2, 3)
    right = MdSpan.from_dense(x, layout=LayoutRight(Extents.fully_dynamic(2, 3)))
    left = MdSpan.from_dense(x, layout=LayoutLeft(Extents.fully_dynamic(2, 3)))
    tiled = MdSpan.from_dense(x, layout=LayoutTiledTPU(Extents.fully_dynamic(2, 3), tile=(2, 2)))
    print("right codomain:", right.codomain().tolist())
    print("left  codomain:", left.codomain().tolist())
    print("tiled codomain:", tiled.codomain().tolist(), "(2x2 hardware tiles, padded)")

    # symmetric packed: non-unique layout; scale() takes the contiguous-codomain path
    sym = MdSpan.from_dense(
        jnp.array([[1.0, 2.0], [2.0, 5.0]]),
        layout=LayoutSymmetricPacked(Extents.fully_dynamic(2, 2)),
    )
    print("packed triangle:", sym.codomain().tolist(), "->", alg.scale(sym, 10).to_dense().tolist())

    # --- accessors: int8 quantized view ---------------------------------------------
    qa = QuantizedAccessor(jnp.float32, bits=8, block=8)
    q = MdSpan.from_dense(jnp.linspace(-1, 1, 32).reshape(4, 8), accessor=qa)
    print("quantized storage dtype:", q.buffers["q"].dtype, "| q(2, 3) =", float(q(2, 3)))
    # accessor-aware scale touches ONLY the scales (64x fewer bytes):
    q2 = alg.scale(q, 2.0)
    print("scaled via scales only; q2(2,3) =", float(q2(2, 3)))


if __name__ == "__main__":
    main()
