"""Quickstart: continuous-batching serving with a paged KV cache.

The engine serves many concurrent generation requests from one fixed-size page
pool. Each sequence's KV cache is a set of fixed-size pages scattered anywhere
in the pool; a per-sequence block table (core.layouts.LayoutPaged — the paper's
layout-mapping customization point on a layout the C++ committee never shipped)
maps logical token positions to (page, slot) storage, and the paged-attention
kernel consumes the table directly. Requests are admitted as pages free up,
batched together mid-flight, and preempted/recomputed under memory pressure —
outputs are bit-identical to running each request alone.

    # serve 6 requests with Poisson arrivals on a small model
    PYTHONPATH=src python examples/serve_engine.py --requests 6 --tokens 8

    # engine in five lines:
    from repro.serving import GenerationParams
    from repro.serving.engine import EngineConfig, ServeEngine
    engine = ServeEngine(model, params, EngineConfig(num_pages=64, page_size=16))
    handle = engine.submit([1, 2, 3], GenerationParams(max_new_tokens=32), rid=0)
    engine.run()                      # handle.sequences -> per-branch Sequence list
    print(engine.metrics())           # tokens/sec, p50/p99 latency, preemptions

Prefix sharing (on by default): requests whose prompts open with the same
token block are mapped onto the SAME physical pages — per-page refcounts plus a
page-granular prompt-hash index give the pool O(unique tokens) capacity, and
copy-on-write privatizes a shared page the first time a sequence appends into
it. ``--shared-prefix N`` demos it: every prompt gets a common N-token system
block and the run reports pages saved vs. sharing disabled.

Quantized KV pages: ``--kv-dtype int8`` (or ``int4``) stores the page pool as
intN bytes with one f32 scale per (page, head) — the mdspan paper's ACCESSOR
customization point composed with the LayoutPaged layout one. Pages, tables,
admission, sharing and CoW behave identically (the allocator never looks at
bytes); the pool just holds ~4x/~8x more KV per byte. The demo runs an f32
engine on the same trace and reports the capacity gain and token agreement
(quantization is lossy: greedy outputs may diverge within a bounded logit
error — the CI bench gates the bound).

Chunked prefill (mixed steps): ``--chunked`` switches the engine from
monolithic prefill (a long prompt stalls every step until its whole prefill
finishes) to page-sized prefill CHUNKS interleaved with decode — each chunk is
formally a submdspan of the sequence's paged cache view, executed by one
compiled chunk step that serves every chunk position and prompt length. The
demo prepends long prompts to the trace, runs a monolithic engine on the same
trace, and reports time-to-first-token p50 for both plus token-exactness; with
prefix sharing, a request whose prompt prefix is already resident skips the
shared pages' prefill COMPUTE (not just their storage) and the demo reports
the skipped tokens.

On-device sampling: ``--temperature/--top-k/--top-p/--seed`` set the
GenerationParams sampling policy on every request — token selection (greedy included)
runs INSIDE the fused serve step, so logits never leave the device and the
decode loop's only per-token transfer is the (B,) chosen ids. Sampling is
seeded per (seed, request id, position): the demo re-runs the sampled trace
through a second engine and asserts the outputs are identical (and the
comparisons below — sharing on/off, chunked vs monolithic — stay exact even
when sampled, because the fold depends on position, never on scheduling).

Multi-step fused decode: ``--multi-step K`` lets the engine run K decode
iterations in ONE on-device loop whenever the scheduler proves the horizon
event-free (no admission, page append, CoW, or finish within K) — append,
attend, sample and feed back without touching the host, amortizing dispatch
over K tokens. Token-exact for any K; the run reports how many steps fused.

Hierarchical KV: ``--host-pool N`` adds a host-RAM page tier of N pages
behind the accessor customization point — the same page pool, one more
memory space. Finished sessions RETIRE their KV pages to the host tier
(content-keyed, retention-windowed) instead of dropping them; a follow-up
request that opens with the same context PREFETCHES those pages back at
admission, so resuming a conversation costs a page copy instead of a prefill
recompute. The demo resumes every session through the tiered engine and
through the identical config with the tier off (one ``dataclasses.replace``
apart) and reports resume TTFT side by side plus token-exactness. Under
memory pressure the same machinery turns preemption into swap-out.

Lifecycle tracing: ``--trace FILE`` records every engine transition (enqueue,
admit, prefill/chunk spans, page appends, CoW, preemption, fused decode
windows, finish) into a bounded in-memory ring and exports it as Chrome
trace-event JSON — open the file in Perfetto (https://ui.perfetto.dev) or
chrome://tracing to see one timeline track per batch slot plus a scheduler
track. Tracing is host-side only: no device work, no extra transfers.

Knobs: ``num_pages`` (pool memory budget), ``page_size`` (tokens per page),
``max_batch`` (decode batch width), ``attn_impl`` ("pallas" routes decode
through the paged flash kernel; "auto" picks by backend), ``kv_dtype``
(f32 | int8 | int4 page representation), ``--chunked`` + ``--chunk-tokens``
(mixed-step prefill), ``--temperature/--top-k/--top-p/--seed`` (on-device
sampling), ``--multi-step`` (fused decode horizon), ``--host-pool`` (host-RAM
page tier for session resume / preemption-as-swap), ``--trace FILE``
(lifecycle trace export).
"""
import argparse
import dataclasses

import jax
import numpy as np

from repro.models import build_model, get_config
from repro.serving import GenerationParams
from repro.serving.engine import EngineConfig, Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--tokens", type=int, default=8)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--rate", type=float, default=20.0, help="arrivals per second")
    ap.add_argument("--attn-impl", default="auto", choices=["auto", "pallas", "jnp"],
                    help="paged-attention path (pallas = the kernel, interpreted off-TPU)")
    ap.add_argument("--shared-prefix", type=int, default=0, metavar="N",
                    help="prepend a common N-token block to every prompt and "
                         "report pages saved by prefix sharing")
    ap.add_argument("--kv-dtype", default="f32", choices=["f32", "int8", "int4"],
                    help="KV page representation (QuantizedAccessor-style intN "
                         "pages + per-(page, head) scales); non-f32 also runs an "
                         "f32 engine and reports the capacity gain")
    ap.add_argument("--chunked", action="store_true",
                    help="mixed-step engine: page-sized prefill chunks "
                         "interleaved with decode; prepends long prompts to the "
                         "trace and compares TTFT against a monolithic engine")
    ap.add_argument("--chunk-tokens", type=int, default=0,
                    help="max tokens per prefill chunk (page multiple; 0 = auto)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (0 = greedy argmax); selection "
                         "always runs on device inside the fused serve step")
    ap.add_argument("--top-k", type=int, default=0,
                    help="keep only the k largest logits before sampling (0 = off)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus sampling: keep the smallest head of the "
                         "distribution with mass top_p (1.0 = off)")
    ap.add_argument("--seed", type=int, default=0,
                    help="sampling PRNG stream seed (per-request streams fold "
                         "the request id; same seed => same tokens, always)")
    ap.add_argument("--multi-step", type=int, default=1, metavar="K",
                    help="fused decode horizon: run K decode iterations in one "
                         "on-device loop over event-free horizons (1 = off)")
    ap.add_argument("--host-pool", type=int, default=0, metavar="N",
                    help="host-RAM page tier of N pages (try 64): finished "
                         "sessions retire their KV pages host-side and resume "
                         "by prefetching them back; the demo compares resume "
                         "TTFT against the same engine with the tier off")
    ap.add_argument("--trace", default="", metavar="FILE",
                    help="record the request-lifecycle trace and export it to "
                         "FILE as Chrome trace-event JSON (view in Perfetto)")
    args = ap.parse_args()

    cfg = dataclasses.replace(get_config(args.arch, smoke=True), dtype="float32")
    model = build_model(cfg)
    params = model.init_params(jax.random.key(0))

    rng = np.random.default_rng(0)
    prefix = rng.integers(0, cfg.vocab, size=args.shared_prefix).tolist()
    arrivals = np.cumsum(rng.exponential(1.0 / args.rate, size=args.requests))
    prompts = [
        prefix + rng.integers(0, cfg.vocab, size=int(rng.choice([6, 10, 14]))).tolist()
        for _ in range(args.requests)
    ]
    long_len = 0
    if args.chunked and not args.shared_prefix:
        # two long prompts at the head of the burst: the monolithic comparison
        # engine must prefill each whole before anything behind them moves.
        # Skipped under --shared-prefix: the longs would hold slots while the
        # same-prefix requests run disjointly, so none would overlap and the
        # sharing demo would (correctly) report zero adoptions.
        long_len = 8 * args.page_size
        prompts = [
            rng.integers(0, cfg.vocab, size=long_len).tolist() for _ in range(2)
        ] + prompts
        arrivals = np.concatenate([[0.0, 0.0], arrivals])
    gen_params = GenerationParams(
        max_new_tokens=args.tokens, temperature=args.temperature,
        top_k=args.top_k, top_p=args.top_p, seed=args.seed,
    )
    make_requests = lambda: [
        Request(rid=i, prompt=list(p), params=gen_params,
                arrival_time=float(arrivals[i]))
        for i, p in enumerate(prompts)
    ]
    econf = EngineConfig.sized_for(
        max(long_len, args.shared_prefix + 14) + args.tokens + 1,
        page_size=args.page_size,
        max_batch=args.max_batch,
        attn_impl=args.attn_impl,
        kv_dtype=args.kv_dtype,
        chunked_prefill=args.chunked,
        chunk_tokens=args.chunk_tokens,
        multi_step=args.multi_step,
        trace=bool(args.trace),
    )

    engine = ServeEngine(model, params, econf)
    results = engine.run(make_requests())
    if args.trace:
        engine.trace.export(args.trace)
        n_ev = len(engine.trace.events)
        print(
            f"lifecycle trace: {n_ev} events -> {args.trace} "
            f"(open in https://ui.perfetto.dev or chrome://tracing)"
        )

    for rid in sorted(results):
        s = results[rid]
        print(f"req {rid}: prompt[{len(s.request.prompt)}] -> {s.generated}")
    m = engine.metrics()
    print(
        f"\n{m['requests']} requests, {m['generated_tokens']} tokens in {m['wall_s']:.2f}s "
        f"({m['tokens_per_s']:.1f} tok/s, CPU demo incl. compiles) | "
        f"latency p50 {m['latency_s_p50']*1e3:.0f}ms p99 {m['latency_s_p99']*1e3:.0f}ms | "
        f"step p50 {m['step_ms_p50']:.2f}ms (host overhead "
        f"{m['host_overhead_ms_p50']:.2f}ms) | preemptions {m['preemptions']}"
    )
    if args.multi_step > 1:
        print(
            f"multi-step fused decode (K={args.multi_step}): "
            f"{m['fused_steps']}/{m['decode_steps']} decode steps ran inside "
            f"on-device fused windows (event-free horizons only; token-exact vs K=1)"
        )
    if args.temperature > 0:
        # seeded sampling is a pure function of (seed, rid, position): a second
        # engine on the same trace must reproduce every token
        rerun = ServeEngine(model, params, econf).run(make_requests())
        assert all(
            results[r].generated == rerun[r].generated for r in results
        ), "seeded sampling must be reproducible"
        print(
            f"on-device sampling: temperature={args.temperature} "
            f"top_k={args.top_k} top_p={args.top_p} seed={args.seed} | "
            f"re-run reproduces all {len(results)} outputs exactly "
            f"(logits never left the device)"
        )

    if args.chunked:
        # same trace through a monolithic-prefill engine: the TTFT cost of
        # stalling every step behind whole-prompt prefills
        mono = ServeEngine(
            model, params, dataclasses.replace(econf, chunked_prefill=False)
        )
        mono_results = mono.run(make_requests())
        mm = mono.metrics()
        agree = sum(
            results[r].generated == mono_results[r].generated for r in results
        )
        if args.kv_dtype == "f32":
            # exactness holds only at full precision: quantized pools pay the
            # intN representation on cross-chunk attention reads where the
            # monolithic engine attends f32 (see ROADMAP — int4 especially)
            assert agree == len(results), "chunked prefill must not change tokens"
            match_note = "outputs identical"
        else:
            match_note = (
                f"outputs match monolithic on {agree}/{len(results)} requests "
                f"(cross-chunk reads pay the {args.kv_dtype} representation)"
            )
        trace = (
            f"a {long_len}-token long-prompt burst" if long_len
            else "the shared-prefix trace"
        )
        print(
            f"chunked prefill: ttft p50 {m['ttft_s_p50']*1e3:.0f}ms vs "
            f"{mm['ttft_s_p50']*1e3:.0f}ms monolithic "
            f"({mm['ttft_s_p50']/max(m['ttft_s_p50'], 1e-9):.1f}x) on {trace} | "
            f"prefill compute: {m['prefill_tokens_computed']} tokens computed, "
            f"{m['prefill_tokens_skipped']} skipped via shared prefixes | "
            f"{match_note}"
        )

    if args.kv_dtype != "f32":
        # same trace at f32: the byte cost of NOT quantizing the page pool
        ref = ServeEngine(model, params, dataclasses.replace(econf, kv_dtype="f32"))
        ref_results = ref.run(make_requests())
        rm = ref.metrics()
        agree = sum(
            results[r].generated == ref_results[r].generated for r in results
        )
        print(
            f"quantized KV ({args.kv_dtype}): pool {m['kv_pool_bytes']} bytes vs "
            f"{rm['kv_pool_bytes']} at f32 -> {rm['kv_pool_bytes']/m['kv_pool_bytes']:.1f}x "
            f"more KV capacity per byte (same {m['peak_pages_in_use']} peak pages) | "
            f"greedy outputs match f32 on {agree}/{len(results)} requests "
            f"(quantization is lossy; the CI bench bounds the logit error)"
        )

    if args.host_pool:
        # hierarchical KV: every finished session is resumed — its full
        # context plus a fresh user tail — through a tiered engine (pages
        # prefetched back from host RAM) and through the identical config one
        # dataclasses.replace away (tier off: full prefill recompute). Each
        # engine rehearses the resume TWICE so the comparison times compiled
        # code — after the first rehearsal the tiered engine retains the
        # resume context itself, so only the second rehearsal runs the exact
        # (smaller) chunk shapes the measured resume will — then measures a
        # final resume of the same contexts.
        resume_tail = rng.integers(0, cfg.vocab, size=8).tolist()
        max_resume = (
            max(len(p) for p in prompts) + 2 * args.tokens
            + len(resume_tail) + 1
        )
        hconf = EngineConfig.sized_for(
            max_resume, page_size=args.page_size, max_batch=args.max_batch,
            attn_impl=args.attn_impl, chunked_prefill=True,
            chunk_tokens=args.chunk_tokens,
            host_pool_pages=args.host_pool, retain_finished_s=600.0,
        )
        tiered = ServeEngine(model, params, hconf)
        untiered = ServeEngine(
            model, params,
            dataclasses.replace(hconf, host_pool_pages=0,
                                retain_finished_s=0.0),
        )
        resumed, rstats = {}, {}
        for name, eng in (("prefetch", tiered), ("recompute", untiered)):
            sessions = eng.run(make_requests())
            resume = lambda base: [
                Request(
                    rid=base + rid,
                    prompt=list(s.request.prompt) + list(s.generated)
                    + resume_tail,
                    params=gen_params,
                )
                for rid, s in sorted(sessions.items())
                if rid < 100
            ]
            eng.run(resume(200))  # rehearsal 1: warms the tier
            eng.run(resume(300))  # rehearsal 2: compiles warm-tier shapes
            eng.reset_metrics()
            out = eng.run(resume(100))
            resumed[name] = {
                r - 100: out[r].generated for r in out if 100 <= r < 200
            }
            rstats[name] = eng.metrics()
        assert resumed["prefetch"] == resumed["recompute"], (
            "host-tier resume must not change tokens"
        )
        wm, cm = rstats["prefetch"], rstats["recompute"]
        print(
            f"hierarchical KV (host pool {args.host_pool} pages): resume "
            f"ttft p50 {wm['ttft_s_p50']*1e3:.1f}ms prefetching vs "
            f"{cm['ttft_s_p50']*1e3:.1f}ms recomputing "
            f"({cm['ttft_s_p50']/max(wm['ttft_s_p50'], 1e-9):.1f}x) | "
            f"{wm['prefetch_hits']} pages prefetched, prefill tokens "
            f"computed {wm['prefill_tokens_computed']} vs "
            f"{cm['prefill_tokens_computed']} | outputs identical"
        )

    if args.shared_prefix:
        # same trace, sharing disabled: the page-pool cost of NOT deduping
        baseline = ServeEngine(
            model, params, dataclasses.replace(econf, prefix_sharing=False)
        )
        base_results = baseline.run(make_requests())
        bm = baseline.metrics()
        assert all(
            results[r].generated == base_results[r].generated for r in results
        ), "prefix sharing must not change tokens"
        saved = bm["peak_pages_in_use"] - m["peak_pages_in_use"]
        print(
            f"prefix sharing: peak pages {m['peak_pages_in_use']} vs "
            f"{bm['peak_pages_in_use']} without -> {saved} pages saved "
            f"({100.0 * saved / max(bm['peak_pages_in_use'], 1):.0f}%) | "
            f"{m['pages_shared']} page adoptions, {m['cow_copies']} CoW copies, "
            f"outputs identical"
        )


if __name__ == "__main__":
    main()
