"""End-to-end training driver: data pipeline → sharded train loop → checkpoints →
auto-resume, on any of the 10 architectures.

CPU demo (a few minutes):
  PYTHONPATH=src python examples/train_lm.py --steps 150
~100M-parameter run (the deliverable configuration; needs real hardware time):
  PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300

Writes the loss history to artifacts/train_history.json (plotted in EXPERIMENTS.md).
"""
import argparse
import dataclasses
import json
from pathlib import Path

from repro.models import get_config
from repro.runtime import RunConfig, TrainerLoop


def preset_cfg(name: str):
    if name == "smoke":  # ~5M params: CPU-friendly demo
        return dict(arch="llama3.2-1b", smoke=True, batch=8, seq=64)
    if name == "100m":  # ~124M params
        return dict(arch="qwen2-0.5b", smoke=False, batch=32, seq=512)
    raise ValueError(name)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="smoke", choices=["smoke", "100m"])
    ap.add_argument("--arch", default=None)
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--ckpt-dir", default="checkpoints/train_lm")
    ap.add_argument("--lr", type=float, default=3e-3)
    args = ap.parse_args()

    p = preset_cfg(args.preset)
    if args.arch:
        p["arch"] = args.arch
    run = RunConfig(
        arch=p["arch"], smoke=p["smoke"], steps=args.steps, batch=p["batch"],
        seq=p["seq"], peak_lr=args.lr, warmup=max(args.steps // 10, 5),
        ckpt_dir=args.ckpt_dir, ckpt_every=max(args.steps // 5, 10), log_every=10,
    )
    loop = TrainerLoop(run)
    out = loop.run_loop()
    hist = out["history"]
    Path("artifacts").mkdir(exist_ok=True)
    Path("artifacts/train_history.json").write_text(json.dumps(hist))
    first = sum(h["loss"] for h in hist[:5]) / max(len(hist[:5]), 1)
    last = sum(h["loss"] for h in hist[-5:]) / max(len(hist[-5:]), 1)
    print(f"\nloss: first5={first:.4f} -> last5={last:.4f} "
          f"({'LEARNED' if last < first else 'no improvement'})")
    print(f"checkpoints in {args.ckpt_dir}; re-run to auto-resume")


if __name__ == "__main__":
    main()
